"""jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, build the query digit planes / parameter
vectors, and enforce the per-step VMEM budget.  Backend dispatch (compiled
on TPU, interpreter elsewhere) happens inside the kernels' own
``interpret=None`` auto-detection.  The wrappers take the same logical
arguments as the pure-jnp oracles in ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.pq_adc import pq_adc
from repro.kernels.ternary_refine import (ternary_refine,
                                          ternary_refine_batch,
                                          ternary_refine_fused,
                                          ternary_refine_fused_bounds)

_ON_TPU = jax.default_backend() == "tpu"

#: Per-core VMEM capacity the kernels budget against (v4/v5e ≈ 16 MiB).
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


class VMEMBudgetError(ValueError):
    """A block_c / level-count combination exceeds the per-step VMEM budget."""


def _check_vmem_budget(*, what: str, block_c: int, g: int, c_pad: int,
                       num_levels: int = 1, fused: bool = False) -> None:
    """Reject block/level configurations whose per-step working set cannot
    fit in VMEM.  Counted per grid step: double-buffered input blocks
    (codes + scalars + level scalars + digit planes + params) plus, for the
    fused kernels, the full-candidate-set scratch (est/lo/hi/alive/delta)
    and resident outputs that persist across level segments."""
    per_step = (block_c * g                # packed codes (uint8)
                + block_c * 8 * 4          # level-0 scalars
                + 5 * g * 4                # query digit planes
                + 8 * 4)                   # params
    if fused:
        per_step += block_c * 4 * 4        # level scalars
    total = 2 * per_step                   # double buffering
    if fused:
        total += 5 * c_pad * 4             # est/lo/hi/alive/delta scratch
        total += (2 * c_pad + 2 * num_levels) * 4   # resident outputs
    if total > VMEM_BUDGET_BYTES:
        raise VMEMBudgetError(
            f"{what}: block_c={block_c} x {num_levels} level(s) over "
            f"{c_pad} padded candidates needs ~{total / 2**20:.1f} MiB of "
            f"VMEM per grid step, over the {VMEM_BUDGET_BYTES / 2**20:.0f} "
            f"MiB per-core budget; lower block_c or the refine budget")


def _pad_rows(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    c = x.shape[0]
    pad = (-c) % mult
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, c


def _pad_axis(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    c = x.shape[axis]
    pad = (-c) % mult
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, c


def _pad_axis1(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    return _pad_axis(x, 1, mult)


@functools.partial(jax.jit, static_argnames=("block_c",))
def refine_scores(packed: jax.Array, q: jax.Array, d0: jax.Array,
                  delta_sq: jax.Array, cross: jax.Array, norm: jax.Array,
                  rho: jax.Array, w: jax.Array, bias: jax.Array,
                  *, block_c: int = 512) -> jax.Array:
    """Fused refine over a candidate batch → (C, 3) [est, est_raw, margin].

    Drop-in accelerated form of core.estimator.refine_level's math.
    """
    c, g = packed.shape
    q_planes = ref.make_query_planes(q.astype(jnp.float32), g)
    scalars = jnp.stack([d0, delta_sq, cross, norm, rho] +
                        [jnp.zeros_like(d0)] * 3, axis=-1)  # (C, 8)
    qn = jnp.linalg.norm(q)
    params = jnp.concatenate([qn[None], w.astype(jnp.float32),
                              bias[None].astype(jnp.float32),
                              jnp.zeros((2,), jnp.float32)])[None, :]  # (1,8)
    packed_p, c0 = _pad_rows(packed, block_c)
    scalars_p, _ = _pad_rows(scalars.astype(jnp.float32), block_c)
    _check_vmem_budget(what="refine_scores", block_c=block_c, g=g,
                       c_pad=packed_p.shape[0])
    out = ternary_refine(packed_p, q_planes, scalars_p, params,
                         block_c=block_c)
    return out[:c0]


def _batch_planes_params(q: jax.Array, g: int, w: jax.Array,
                         bias: jax.Array, extra: jax.Array | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """Per-query digit planes (Q, 5, G) + params (Q, 8)
    [qn, w0..w3, bias, extra0, extra1]."""
    q32 = q.astype(jnp.float32)
    nq = q32.shape[0]
    q_planes = jax.vmap(lambda qq: ref.make_query_planes(qq, g))(q32)
    qn = jnp.linalg.norm(q32, axis=-1)                          # (Q,)
    wb = jnp.concatenate([w.astype(jnp.float32),
                          bias[None].astype(jnp.float32)])
    params = jnp.concatenate(
        [qn[:, None], jnp.broadcast_to(wb, (nq, 5)),
         jnp.zeros((nq, 2), jnp.float32) if extra is None
         else jnp.broadcast_to(extra, (nq, 2))], axis=1)        # (Q, 8)
    return q_planes, params


@functools.partial(jax.jit, static_argnames=("block_c",))
def refine_scores_batch(packed: jax.Array, q: jax.Array, d0: jax.Array,
                        delta_sq: jax.Array, cross: jax.Array,
                        norm: jax.Array, rho: jax.Array, w: jax.Array,
                        bias: jax.Array, *, block_c: int = 512) -> jax.Array:
    """Fused refine over a query micro-batch → (Q, C, 3).

    packed (Q, C, G) per-query gathered codes, q (Q, D), per-record scalars
    (Q, C); calibration w (4,) + bias are shared across queries.  Same math
    as ``refine_scores`` run once per query, in a single kernel launch.
    """
    nq, c, g = packed.shape
    q_planes, params = _batch_planes_params(q, g, w, bias)
    scalars = jnp.stack([d0, delta_sq, cross, norm, rho] +
                        [jnp.zeros_like(d0)] * 3, axis=-1)     # (Q, C, 8)
    packed_p, c0 = _pad_axis1(packed, block_c)
    scalars_p, _ = _pad_axis1(scalars.astype(jnp.float32), block_c)
    _check_vmem_budget(what="refine_scores_batch", block_c=block_c, g=g,
                       c_pad=packed_p.shape[1])
    out = ternary_refine_batch(packed_p, q_planes, scalars_p, params,
                               block_c=block_c)
    return out[:, :c0]


def _fused_inputs(packed_levels, q, d0, delta_sq, cross, norm, rho, valid,
                  is_delta, lvl_proj, lvl_norm, lvl_rho, w, bias, resid_std,
                  z, block_c):
    """Shared input assembly for the fused kernels: gather/stack the
    level-0 scalar plane (valid + is_delta flags in slots 5/6), the
    per-level [proj, norm, rho] planes, and the per-query params with
    [z·resid_std, resid_std] in the extra slots; pad candidates to a
    block_c multiple (padded slots have valid=0, so they never survive)."""
    l, nq, c, g = packed_levels.shape
    rs = jnp.asarray(resid_std, jnp.float32)
    extra = jnp.stack([jnp.float32(z) * rs, rs])
    q_planes, params = _batch_planes_params(q, g, w, bias, extra)
    zeros = jnp.zeros_like(d0)
    scalars = jnp.stack(
        [d0, delta_sq, cross, norm, rho, valid.astype(jnp.float32),
         is_delta.astype(jnp.float32), zeros], axis=-1)         # (Q, C, 8)
    level_scalars = jnp.stack(
        [lvl_proj, lvl_norm, lvl_rho, jnp.zeros_like(lvl_proj)],
        axis=-1)                                                # (L, Q, C, 4)
    packed_p, c0 = _pad_axis(packed_levels, 2, block_c)
    scalars_p, _ = _pad_axis(scalars.astype(jnp.float32), 1, block_c)
    lvl_p, _ = _pad_axis(level_scalars.astype(jnp.float32), 2, block_c)
    return packed_p, q_planes, scalars_p, lvl_p, params, c0


@functools.partial(jax.jit, static_argnames=("k", "bound", "block_c"))
def fused_refine_scores_batch(packed_levels: jax.Array, q: jax.Array,
                              d0: jax.Array, delta_sq: jax.Array,
                              cross: jax.Array, norm: jax.Array,
                              rho: jax.Array, valid: jax.Array,
                              is_delta: jax.Array, lvl_proj: jax.Array,
                              lvl_norm: jax.Array, lvl_rho: jax.Array,
                              w: jax.Array, bias: jax.Array,
                              resid_std: jax.Array, z: float, *, k: int,
                              bound: str, block_c: int = 512
                              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Whole progressive-refinement loop in ONE kernel launch.

    packed_levels (L, Q, C, G) per-level gathered codes; q (Q, D);
    level-0 scalars d0/delta_sq/cross/norm/rho + masks valid/is_delta all
    (Q, C); per-level lvl_proj/lvl_norm/lvl_rho (L, Q, C); calibration
    w (4,)/bias; resid_std + quantile width z for the certified margins.

    Returns (est (Q, C), alive (Q, C) bool, counts (Q, 2L) int32) — counts
    rows are [survivors after level 0..L−1, then the delta-page survivor
    split for the ledger].  Thresholds are computed on-chip, so this form
    is for unsharded execution; sharded callers use
    ``fused_refine_bounds_batch`` and pool thresholds across the mesh.
    """
    inputs = _fused_inputs(packed_levels, q, d0, delta_sq, cross, norm, rho,
                           valid, is_delta, lvl_proj, lvl_norm, lvl_rho, w,
                           bias, resid_std, z, block_c)
    packed_p, q_planes, scalars_p, lvl_p, params, c0 = inputs
    l, g = packed_levels.shape[0], packed_levels.shape[3]
    _check_vmem_budget(what="fused_refine_scores_batch", block_c=block_c,
                       g=g, c_pad=packed_p.shape[2], num_levels=l,
                       fused=True)
    est, alive, counts = ternary_refine_fused(
        packed_p, q_planes, scalars_p, lvl_p, params, k=k, bound=bound,
        block_c=block_c)
    return est[:, :c0], alive[:, :c0].astype(bool), counts


@functools.partial(jax.jit, static_argnames=("bound", "block_c"))
def fused_refine_bounds_batch(packed_levels: jax.Array, q: jax.Array,
                              d0: jax.Array, delta_sq: jax.Array,
                              cross: jax.Array, norm: jax.Array,
                              rho: jax.Array, valid: jax.Array,
                              is_delta: jax.Array, lvl_proj: jax.Array,
                              lvl_norm: jax.Array, lvl_rho: jax.Array,
                              w: jax.Array, bias: jax.Array,
                              resid_std: jax.Array, z: float, *, bound: str,
                              block_c: int = 512
                              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sharded companion of ``fused_refine_scores_batch``: identical inputs
    and single-launch level stacking, returning (est (Q, C), lo (Q, L, C),
    hi (Q, L, C)) so the caller can exchange pruning thresholds globally
    (``pooled_k_smallest`` over the mesh axis) between level segments."""
    inputs = _fused_inputs(packed_levels, q, d0, delta_sq, cross, norm, rho,
                           valid, is_delta, lvl_proj, lvl_norm, lvl_rho, w,
                           bias, resid_std, z, block_c)
    packed_p, q_planes, scalars_p, lvl_p, params, c0 = inputs
    l, g = packed_levels.shape[0], packed_levels.shape[3]
    _check_vmem_budget(what="fused_refine_bounds_batch", block_c=block_c,
                       g=g, c_pad=packed_p.shape[2], num_levels=l,
                       fused=True)
    est, lo, hi = ternary_refine_fused_bounds(
        packed_p, q_planes, scalars_p, lvl_p, params, bound=bound,
        block_c=block_c)
    return est[:, :c0], lo[:, :, :c0], hi[:, :, :c0]


@functools.partial(jax.jit, static_argnames=("block_c",))
def adc_scores(codes: jax.Array, lut: jax.Array, *, block_c: int = 128
               ) -> jax.Array:
    """PQ-ADC distances for a candidate batch → (C,)."""
    codes_p, c0 = _pad_rows(codes, block_c)
    return pq_adc(codes_p, lut.astype(jnp.float32), block_c=block_c,
                  interpret=not _ON_TPU)[:c0]
