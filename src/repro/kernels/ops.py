"""jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, build the query digit planes / parameter
vectors, and dispatch to interpret mode on CPU (the container) vs compiled
mode on TPU.  The wrappers take the same logical arguments as the pure-jnp
oracles in ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.pq_adc import pq_adc
from repro.kernels.ternary_refine import ternary_refine, ternary_refine_batch

_ON_TPU = jax.default_backend() == "tpu"


def _pad_rows(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    c = x.shape[0]
    pad = (-c) % mult
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, c


def _pad_axis1(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    c = x.shape[1]
    pad = (-c) % mult
    if pad:
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2)
        x = jnp.pad(x, widths)
    return x, c


@functools.partial(jax.jit, static_argnames=("block_c",))
def refine_scores(packed: jax.Array, q: jax.Array, d0: jax.Array,
                  delta_sq: jax.Array, cross: jax.Array, norm: jax.Array,
                  rho: jax.Array, w: jax.Array, bias: jax.Array,
                  *, block_c: int = 512) -> jax.Array:
    """Fused refine over a candidate batch → (C, 3) [est, est_raw, margin].

    Drop-in accelerated form of core.estimator.refine_level's math.
    """
    c, g = packed.shape
    q_planes = ref.make_query_planes(q.astype(jnp.float32), g)
    scalars = jnp.stack([d0, delta_sq, cross, norm, rho] +
                        [jnp.zeros_like(d0)] * 3, axis=-1)  # (C, 8)
    qn = jnp.linalg.norm(q)
    params = jnp.concatenate([qn[None], w.astype(jnp.float32),
                              bias[None].astype(jnp.float32),
                              jnp.zeros((2,), jnp.float32)])[None, :]  # (1,8)
    packed_p, c0 = _pad_rows(packed, block_c)
    scalars_p, _ = _pad_rows(scalars.astype(jnp.float32), block_c)
    out = ternary_refine(packed_p, q_planes, scalars_p, params,
                         block_c=block_c, interpret=not _ON_TPU)
    return out[:c0]


@functools.partial(jax.jit, static_argnames=("block_c",))
def refine_scores_batch(packed: jax.Array, q: jax.Array, d0: jax.Array,
                        delta_sq: jax.Array, cross: jax.Array,
                        norm: jax.Array, rho: jax.Array, w: jax.Array,
                        bias: jax.Array, *, block_c: int = 512) -> jax.Array:
    """Fused refine over a query micro-batch → (Q, C, 3).

    packed (Q, C, G) per-query gathered codes, q (Q, D), per-record scalars
    (Q, C); calibration w (4,) + bias are shared across queries.  Same math
    as ``refine_scores`` run once per query, in a single kernel launch.
    """
    nq, c, g = packed.shape
    q32 = q.astype(jnp.float32)
    q_planes = jax.vmap(lambda qq: ref.make_query_planes(qq, g))(q32)
    scalars = jnp.stack([d0, delta_sq, cross, norm, rho] +
                        [jnp.zeros_like(d0)] * 3, axis=-1)     # (Q, C, 8)
    qn = jnp.linalg.norm(q32, axis=-1)                          # (Q,)
    wb = jnp.concatenate([w.astype(jnp.float32),
                          bias[None].astype(jnp.float32),
                          jnp.zeros((2,), jnp.float32)])
    params = jnp.concatenate([qn[:, None],
                              jnp.broadcast_to(wb, (nq, 7))], axis=1)  # (Q,8)
    packed_p, c0 = _pad_axis1(packed, block_c)
    scalars_p, _ = _pad_axis1(scalars.astype(jnp.float32), block_c)
    out = ternary_refine_batch(packed_p, q_planes, scalars_p, params,
                               block_c=block_c, interpret=not _ON_TPU)
    return out[:, :c0]


@functools.partial(jax.jit, static_argnames=("block_c",))
def adc_scores(codes: jax.Array, lut: jax.Array, *, block_c: int = 128
               ) -> jax.Array:
    """PQ-ADC distances for a candidate batch → (C,)."""
    codes_p, c0 = _pad_rows(codes, block_c)
    return pq_adc(codes_p, lut.astype(jnp.float32), block_c=block_c,
                  interpret=not _ON_TPU)[:c0]
