"""Fused FaTRQ refinement Pallas kernel — the paper's CXL accelerator
datapath, re-expressed for the TPU memory hierarchy.

The paper streams packed ternary codes from far memory into a small decoder
LUT + add/sub datapath.  On TPU the analogous structure is: packed codes
live in HBM at 1.6 bit/dim (the "far" tier), each grid step DMAs one
candidate block into VMEM (the "near" tier), and the VPU unpacks + scores
it without ever materializing full-precision residuals in HBM.  The fusion
(unpack → ternary inner product → calibrated estimate → certified margin)
is the whole point: HBM traffic is ⌈D/5⌉+20 bytes per candidate instead of
4·D for full vectors — the bandwidth form of the paper's "no multiplies".

Layout note: base-3 digit i of byte g holds dim 5g+i, so the query is
pre-arranged into 5 digit planes of (G,) (see ref.make_query_planes) and
unpacking is 5 div/mod passes over the byte block — no reshapes, no
gathers, fully vectorized on 8×128 VPU tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_POW3 = (1, 3, 9, 27, 81)


def _score_block(y, qplanes, scal, params):
    """Shared scoring math: one candidate block of one query.

    y (BC, G) int32 packed bytes, qplanes (5, G), scal (BC, 8), params (8,)
    → (est, est_raw, margin), each (BC,).  Both kernels call this; only the
    ref slicing differs between the single-query and batched grids.
    """
    qn = params[0]
    w0, w1, w2, w3, bias = params[1], params[2], params[3], params[4], \
        params[5]

    acc = jnp.zeros(y.shape, jnp.float32)
    kcnt = jnp.zeros(y.shape, jnp.int32)
    for i in range(5):
        digit = (y // _POW3[i]) % 3 - 1            # (BC, G) ∈ {-1,0,1}
        trit = digit.astype(jnp.float32)
        acc = acc + trit * qplanes[i, :][None, :]
        kcnt = kcnt + digit * digit
    raw = jnp.sum(acc, axis=1)                     # Σ c·q        (BC,)
    k = jnp.sum(kcnt, axis=1).astype(jnp.float32)  # ||c||²       (BC,)
    align = raw / jnp.sqrt(jnp.maximum(k, 1.0))    # Σ c·q / √k

    d0 = scal[:, 0]
    delta_sq = scal[:, 1]
    cross = scal[:, 2]
    norm = scal[:, 3]
    rho = scal[:, 4]

    e_align = align / jnp.maximum(qn, 1e-30)
    d_ip = -2.0 * norm * rho * align
    est = w0 * d0 + w1 * d_ip + w2 * delta_sq + w3 * cross + bias
    est_raw = d0 + delta_sq + 2.0 * cross + d_ip
    margin = (2.0 * qn * norm
              * jnp.sqrt(jnp.clip(1.0 - e_align * e_align, 0.0, 1.0))
              * jnp.sqrt(jnp.clip(1.0 - rho * rho, 0.0, 1.0)))
    return est, est_raw, margin


def _refine_kernel(packed_ref, qplanes_ref, scal_ref, params_ref, out_ref):
    """One candidate block: (BC, G) bytes → (BC, 3) [est, est_raw, margin]."""
    est, est_raw, margin = _score_block(packed_ref[...].astype(jnp.int32),
                                        qplanes_ref[...], scal_ref[...],
                                        params_ref[0])
    out_ref[:, 0] = est
    out_ref[:, 1] = est_raw
    out_ref[:, 2] = margin


def _refine_kernel_batch(packed_ref, qplanes_ref, scal_ref, params_ref,
                         out_ref):
    """Query-batched variant: block shapes carry a leading (1,) query dim.

    Grid is (Q, C/BC); each step scores one candidate block of one query, so
    a whole micro-batch of queries runs as a single kernel launch — the
    executor's batched refinement datapath.
    """
    est, est_raw, margin = _score_block(packed_ref[0].astype(jnp.int32),
                                        qplanes_ref[0], scal_ref[0],
                                        params_ref[0])
    out_ref[0, :, 0] = est
    out_ref[0, :, 1] = est_raw
    out_ref[0, :, 2] = margin


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def ternary_refine_batch(packed: jax.Array, q_planes: jax.Array,
                         scalars: jax.Array, params: jax.Array, *,
                         block_c: int = 512, interpret: bool = True
                         ) -> jax.Array:
    """Multi-query fused refine: one launch scores Q×C candidates.

    packed (Q, C, G) uint8 — per-query gathered codes; q_planes (Q, 5, G);
    scalars (Q, C, 8) f32 [d0, ||δ||², ⟨x_c,δ⟩, ||δ||, rho, 0…];
    params (Q, 8) f32 [qn, w0..w3, b, 0, 0] (w/b normally shared, qn per
    query) → (Q, C, 3) f32 [est, est_raw, margin].

    C must be a multiple of block_c (ops.py pads).  The grid walks queries
    in the outer dimension so each query's candidate blocks stream through
    VMEM back-to-back with its (5, G) digit planes held resident.
    """
    nq, c, g = packed.shape
    assert c % block_c == 0, (c, block_c)
    grid = (nq, c // block_c)
    return pl.pallas_call(
        _refine_kernel_batch,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, g), lambda qi, ci: (qi, ci, 0)),
            pl.BlockSpec((1, 5, g), lambda qi, ci: (qi, 0, 0)),
            pl.BlockSpec((1, block_c, 8), lambda qi, ci: (qi, ci, 0)),
            pl.BlockSpec((1, 8), lambda qi, ci: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, 4), lambda qi, ci: (qi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, c, 4), jnp.float32),
        interpret=interpret,
    )(packed, q_planes, scalars, params)[..., :3]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def ternary_refine(packed: jax.Array, q_planes: jax.Array, scalars: jax.Array,
                   params: jax.Array, *, block_c: int = 512,
                   interpret: bool = True) -> jax.Array:
    """packed (C, G) uint8, q_planes (5, G) f32, scalars (C, 5) f32
    [d0, ||δ||², ⟨x_c,δ⟩, ||δ||, rho], params (1, 8) f32
    [qn, w0..w3, b, 0, 0] → (C, 3) f32.

    C must be a multiple of block_c (ops.py pads).  VMEM per step:
    block_c·G bytes of codes + 5·G query floats + block_c·5 scalars —
    e.g. 512×154 ≈ 77 KiB codes, well within a v5e core's ~128 MiB VMEM
    budget; block_c is sized so several steps double-buffer.
    """
    c, g = packed.shape
    assert c % block_c == 0, (c, block_c)
    grid = (c // block_c,)
    return pl.pallas_call(
        _refine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, g), lambda i: (i, 0)),
            pl.BlockSpec((5, g), lambda i: (0, 0)),
            pl.BlockSpec((block_c, 8), lambda i: (i, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 4), jnp.float32),
        interpret=interpret,
    )(packed, q_planes, scalars, params)[:, :3]
