"""Fused FaTRQ refinement Pallas kernels — the paper's CXL accelerator
datapath, re-expressed for the TPU memory hierarchy.

The paper streams packed ternary codes from far memory into a small decoder
LUT + add/sub datapath with per-level early exit.  On TPU the analogous
structure is: packed codes live in HBM at 1.6 bit/dim (the "far" tier),
each grid step DMAs one candidate block into VMEM (the "near" tier), and
the VPU unpacks + scores it without ever materializing full-precision
residuals in HBM.  HBM traffic is ⌈D/5⌉+20 bytes per candidate instead of
4·D for full vectors — the bandwidth form of the paper's "no multiplies".

Three kernels share the digit-plane scoring body:

* ``ternary_refine`` / ``ternary_refine_batch`` — level-0 scoring only:
  unpack → ternary inner product → calibrated estimate → certified margin
  for one candidate block per grid step.
* ``ternary_refine_fused`` — the WHOLE progressive-refinement loop in one
  ``pallas_call``: the grid walks ``(query, level, candidate-block)`` with
  the level segments sequential, the running estimate / certified bounds /
  alive mask resident in VMEM scratch across segments, the per-level
  pruning threshold (kth-smallest upper bound among survivors) computed
  on-chip and carried in SMEM scratch, and per-level survivor counts
  (total + delta-page split) emitted for the cost ledger.  Intermediate
  estimates and masks never round-trip through HBM.
* ``ternary_refine_fused_bounds`` — the sharded variant of the same
  single-launch datapath: level stacking still happens entirely in VMEM
  scratch, but instead of masking on-chip it emits each level's certified
  ``(lo, hi)`` interval so the caller can pool pruning thresholds globally
  across a mesh axis (``shard_map`` collectives cannot run inside a
  kernel); the alive chain applied outside is arithmetically identical.

Layout note: base-3 digit i of byte g holds dim 5g+i, so the query is
pre-arranged into 5 digit planes of (G,) (see ref.make_query_planes) and
unpacking is 5 div/mod passes over the byte block — no reshapes, no
gathers, fully vectorized on 8×128 VPU tiles.

``interpret`` defaults to backend auto-detection (compiled on TPU,
interpreter elsewhere); pass an explicit bool only to force a mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_POW3 = (1, 3, 9, 27, 81)

_ON_TPU = jax.default_backend() == "tpu"


def _resolve_interpret(interpret: bool | None) -> bool:
    """None → auto-detect: compiled on TPU, interpreter everywhere else."""
    return (not _ON_TPU) if interpret is None else bool(interpret)


def _block_align(y, qplanes):
    """Digit-plane unpack + ternary inner product for one candidate block.

    y (BC, G) int32 packed bytes, qplanes (5, G) → align (BC,) = Σc·q/√k,
    the ⟨q, e_code⟩ term every level's estimate update consumes.
    """
    acc = jnp.zeros(y.shape, jnp.float32)
    kcnt = jnp.zeros(y.shape, jnp.int32)
    for i in range(5):
        digit = (y // _POW3[i]) % 3 - 1            # (BC, G) ∈ {-1,0,1}
        trit = digit.astype(jnp.float32)
        acc = acc + trit * qplanes[i, :][None, :]
        kcnt = kcnt + digit * digit
    raw = jnp.sum(acc, axis=1)                     # Σ c·q        (BC,)
    k = jnp.sum(kcnt, axis=1).astype(jnp.float32)  # ||c||²       (BC,)
    return raw / jnp.sqrt(jnp.maximum(k, 1.0))     # Σ c·q / √k


def _score_block(y, qplanes, scal, params):
    """Shared level-0 scoring math: one candidate block of one query.

    y (BC, G) int32 packed bytes, qplanes (5, G), scal (BC, 8), params (8,)
    → (est, est_raw, margin), each (BC,).  All kernels call this; only the
    ref slicing differs between the single-query and batched grids.
    """
    qn = params[0]
    w0, w1, w2, w3, bias = params[1], params[2], params[3], params[4], \
        params[5]

    align = _block_align(y, qplanes)

    d0 = scal[:, 0]
    delta_sq = scal[:, 1]
    cross = scal[:, 2]
    norm = scal[:, 3]
    rho = scal[:, 4]

    e_align = align / jnp.maximum(qn, 1e-30)
    d_ip = -2.0 * norm * rho * align
    est = w0 * d0 + w1 * d_ip + w2 * delta_sq + w3 * cross + bias
    est_raw = d0 + delta_sq + 2.0 * cross + d_ip
    margin = (2.0 * qn * norm
              * jnp.sqrt(jnp.clip(1.0 - e_align * e_align, 0.0, 1.0))
              * jnp.sqrt(jnp.clip(1.0 - rho * rho, 0.0, 1.0)))
    return est, est_raw, margin


def _kth_smallest(vals, k: int):
    """kth-smallest VALUE of a 1-D vector (the pruning threshold τ).

    Matches ``estimator.pooled_k_smallest`` on the same multiset: the kth
    order statistic is tie-invariant, so extracting k−1 minima (masking one
    occurrence each round with an iota match) and taking the remaining min
    is exactly the value ``lax.top_k`` would return.  k is static and
    small (final_k), so the loop unrolls to k VPU reductions.
    """
    v = vals
    for _ in range(k - 1):
        idx = jnp.argmin(v)
        iota = jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
        v = jnp.where(iota == idx, jnp.inf, v)
    return jnp.min(v)


def _level0_bounds(est, est_raw, margin, params, bound: str):
    """Certified (lo, hi) for level 0 under the configured bound."""
    if bound == "cauchy":
        return est_raw - margin, est_raw + margin
    if bound == "quantile":
        qm = params[6]                             # z · resid_std
        return est - qm, est + qm
    raise ValueError(f"unknown bound {bound!r}")


def _deeper_bounds(est_prev, y, qplanes, lsc, params):
    """Level-ℓ≥1 stacking for one block: est −= 2·proj·align, certified
    margin 2·||q||·||δ_rem|| + resid_std (what trq.progressive_search
    computes).  lsc (BC, 4) = [proj, norm, rho, ·]."""
    qn, resid_std = params[0], params[7]
    align = _block_align(y, qplanes)
    est = est_prev - 2.0 * lsc[:, 0] * align
    rem = lsc[:, 1] * jnp.sqrt(
        jnp.clip(1.0 - lsc[:, 2] * lsc[:, 2], 0.0, 1.0))
    marg = 2.0 * qn * rem + resid_std
    return est, est - marg, est + marg


# --------------------------------------------------------- level-0 kernels


def _refine_kernel(packed_ref, qplanes_ref, scal_ref, params_ref, out_ref):
    """One candidate block: (BC, G) bytes → (BC, 3) [est, est_raw, margin]."""
    est, est_raw, margin = _score_block(packed_ref[...].astype(jnp.int32),
                                        qplanes_ref[...], scal_ref[...],
                                        params_ref[0])
    out_ref[:, 0] = est
    out_ref[:, 1] = est_raw
    out_ref[:, 2] = margin


def _refine_kernel_batch(packed_ref, qplanes_ref, scal_ref, params_ref,
                         out_ref):
    """Query-batched variant: block shapes carry a leading (1,) query dim.

    Grid is (Q, C/BC); each step scores one candidate block of one query, so
    a whole micro-batch of queries runs as a single kernel launch — the
    executor's batched level-0 datapath (the fully fused multi-level loop
    is ``_fused_kernel`` below).
    """
    est, est_raw, margin = _score_block(packed_ref[0].astype(jnp.int32),
                                        qplanes_ref[0], scal_ref[0],
                                        params_ref[0])
    out_ref[0, :, 0] = est
    out_ref[0, :, 1] = est_raw
    out_ref[0, :, 2] = margin


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def ternary_refine_batch(packed: jax.Array, q_planes: jax.Array,
                         scalars: jax.Array, params: jax.Array, *,
                         block_c: int = 512, interpret: bool | None = None
                         ) -> jax.Array:
    """Multi-query level-0 refine: one launch scores Q×C candidates.

    packed (Q, C, G) uint8 — per-query gathered codes; q_planes (Q, 5, G);
    scalars (Q, C, 8) f32 [d0, ||δ||², ⟨x_c,δ⟩, ||δ||, rho, 0…];
    params (Q, 8) f32 [qn, w0..w3, b, 0, 0] (w/b normally shared, qn per
    query) → (Q, C, 3) f32 [est, est_raw, margin].

    C must be a multiple of block_c (ops.py pads).  The grid walks queries
    in the outer dimension so each query's candidate blocks stream through
    VMEM back-to-back with its (5, G) digit planes held resident.
    ``interpret=None`` auto-detects the backend (compiled on TPU).
    """
    nq, c, g = packed.shape
    assert c % block_c == 0, (c, block_c)
    grid = (nq, c // block_c)
    return pl.pallas_call(
        _refine_kernel_batch,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, g), lambda qi, ci: (qi, ci, 0)),
            pl.BlockSpec((1, 5, g), lambda qi, ci: (qi, 0, 0)),
            pl.BlockSpec((1, block_c, 8), lambda qi, ci: (qi, ci, 0)),
            pl.BlockSpec((1, 8), lambda qi, ci: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, 4), lambda qi, ci: (qi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, c, 4), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(packed, q_planes, scalars, params)[..., :3]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def ternary_refine(packed: jax.Array, q_planes: jax.Array, scalars: jax.Array,
                   params: jax.Array, *, block_c: int = 512,
                   interpret: bool | None = None) -> jax.Array:
    """packed (C, G) uint8, q_planes (5, G) f32, scalars (C, 5) f32
    [d0, ||δ||², ⟨x_c,δ⟩, ||δ||, rho], params (1, 8) f32
    [qn, w0..w3, b, 0, 0] → (C, 3) f32.

    C must be a multiple of block_c (ops.py pads).  VMEM per step:
    block_c·G bytes of codes + 5·G query floats + block_c·8 scalars —
    e.g. 512×154 ≈ 77 KiB codes, a small slice of a TPU core's ~16 MiB
    VMEM, so several steps double-buffer (ops.py enforces the budget).
    ``interpret=None`` auto-detects the backend (compiled on TPU).
    """
    c, g = packed.shape
    assert c % block_c == 0, (c, block_c)
    grid = (c // block_c,)
    return pl.pallas_call(
        _refine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, g), lambda i: (i, 0)),
            pl.BlockSpec((5, g), lambda i: (0, 0)),
            pl.BlockSpec((block_c, 8), lambda i: (i, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 4), jnp.float32),
        interpret=_resolve_interpret(interpret),
    )(packed, q_planes, scalars, params)[:, :3]


# ------------------------------------------- fused multi-level kernels
#
# Grid (Q, L, C/BC): for each query, the level segments run sequentially
# (TPU grids are sequential on a core), each walking the candidate blocks.
# The running estimate, certified (lo, hi) interval, alive mask and
# delta-page flag live in (C,) VMEM scratch that persists across segments;
# per-level thresholds live in SMEM scratch.  Only the FINAL estimate,
# alive mask and per-level survivor counts ever reach HBM.


def _fused_kernel(packed_ref, qplanes_ref, scal0_ref, lvls_ref, params_ref,
                  est_out, alive_out, counts_out,
                  est_s, lo_s, hi_s, alive_s, delta_s, tau_s, *,
                  num_levels: int, n_blocks: int, block_c: int, k: int,
                  bound: str):
    """Fully fused datapath: score, stack, threshold, mask, count — on chip.

    scal0 (BC, 8) = [d0, ||δ||², ⟨x_c,δ⟩, ||δ||, rho, valid, is_delta, ·];
    lvls (BC, 4) = level-ℓ [proj, norm, rho, ·];
    params (8,) = [qn, w0..w3, bias, z·resid_std, resid_std].
    counts_out (1, 2L): slots [0, L) hold Σ alive after each level, slots
    [L, 2L) the delta-page survivor split the ledger bills to delta:cxl.
    """
    lv = pl.program_id(1)
    ci = pl.program_id(2)
    blk = pl.ds(ci * block_c, block_c)
    params = params_ref[0]
    y = packed_ref[0, 0].astype(jnp.int32)
    qplanes = qplanes_ref[0]

    @pl.when(lv == 0)
    def _level0():
        scal = scal0_ref[0]
        est, est_raw, margin = _score_block(y, qplanes, scal, params)
        lo, hi = _level0_bounds(est, est_raw, margin, params, bound)
        est_s[blk] = est
        lo_s[blk] = lo
        hi_s[blk] = hi
        alive_s[blk] = scal[:, 5]
        delta_s[blk] = scal[:, 6]

    @pl.when(lv > 0)
    def _deeper():
        est, lo, hi = _deeper_bounds(est_s[blk], y, qplanes,
                                     lvls_ref[0, 0], params)
        est_s[blk] = est
        lo_s[blk] = lo
        hi_s[blk] = hi

    @pl.when(ci == n_blocks - 1)
    def _prune_level():
        # end of a level segment: every block's bounds are in scratch, so
        # the pruning threshold (kth-smallest upper bound among survivors)
        # is computable on-chip; carry it through SMEM and update the alive
        # mask + survivor counters for the whole candidate set at once.
        amask = alive_s[...] > 0.0
        tau_s[lv] = _kth_smallest(jnp.where(amask, hi_s[...], jnp.inf), k)
        alive_new = amask & (lo_s[...] <= tau_s[lv])
        alive_s[...] = alive_new.astype(jnp.float32)
        counts_out[0, lv] = jnp.sum(alive_new.astype(jnp.int32))
        is_delta = delta_s[...] > 0.0
        counts_out[0, num_levels + lv] = jnp.sum(
            (alive_new & is_delta).astype(jnp.int32))

    @pl.when(jnp.logical_and(lv == num_levels - 1, ci == n_blocks - 1))
    def _emit():
        est_out[0, :] = est_s[...]
        alive_out[0, :] = (alive_s[...] > 0.0).astype(jnp.int32)


def _fused_bounds_kernel(packed_ref, qplanes_ref, scal0_ref, lvls_ref,
                         params_ref, est_out, lo_out, hi_out, est_s, *,
                         num_levels: int, n_blocks: int, block_c: int,
                         bound: str):
    """Sharded variant: same single-launch VMEM level stacking, but emit
    each level's certified (lo, hi) instead of masking on-chip — pruning
    thresholds must be pooled ACROSS shards (a mesh collective), which
    cannot run inside a kernel.  The caller's alive chain over these
    bounds is arithmetically identical to ``_fused_kernel``'s."""
    lv = pl.program_id(1)
    ci = pl.program_id(2)
    blk = pl.ds(ci * block_c, block_c)
    params = params_ref[0]
    y = packed_ref[0, 0].astype(jnp.int32)
    qplanes = qplanes_ref[0]

    @pl.when(lv == 0)
    def _level0():
        est, est_raw, margin = _score_block(y, qplanes, scal0_ref[0], params)
        lo, hi = _level0_bounds(est, est_raw, margin, params, bound)
        est_s[blk] = est
        lo_out[0, 0] = lo
        hi_out[0, 0] = hi

    @pl.when(lv > 0)
    def _deeper():
        est, lo, hi = _deeper_bounds(est_s[blk], y, qplanes,
                                     lvls_ref[0, 0], params)
        est_s[blk] = est
        lo_out[0, 0] = lo
        hi_out[0, 0] = hi

    @pl.when(lv == num_levels - 1)
    def _emit():
        est_out[0] = est_s[blk]


def _fused_in_specs(block_c: int, g: int):
    """Input block specs shared by both fused kernels (grid (Q, L, B))."""
    return [
        pl.BlockSpec((1, 1, block_c, g), lambda qi, lv, ci: (lv, qi, ci, 0)),
        pl.BlockSpec((1, 5, g), lambda qi, lv, ci: (qi, 0, 0)),
        pl.BlockSpec((1, block_c, 8), lambda qi, lv, ci: (qi, ci, 0)),
        pl.BlockSpec((1, 1, block_c, 4), lambda qi, lv, ci: (lv, qi, ci, 0)),
        pl.BlockSpec((1, 8), lambda qi, lv, ci: (qi, 0)),
    ]


@functools.partial(jax.jit, static_argnames=("k", "bound", "block_c",
                                             "interpret"))
def ternary_refine_fused(packed: jax.Array, q_planes: jax.Array,
                         scalars: jax.Array, level_scalars: jax.Array,
                         params: jax.Array, *, k: int, bound: str,
                         block_c: int = 512, interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Persistent multi-level refine: ALL TRQ levels in one launch.

    packed (L, Q, C, G) uint8 per-level per-query gathered codes;
    q_planes (Q, 5, G); scalars (Q, C, 8) f32
    [d0, ||δ||², ⟨x_c,δ⟩, ||δ||, rho, valid, is_delta, ·];
    level_scalars (L, Q, C, 4) f32 [proj, norm, rho, ·] (level-0 plane is
    a placeholder — level 0 scores from ``scalars``); params (Q, 8) f32
    [qn, w0..w3, bias, z·resid_std, resid_std].

    Returns (est (Q, C) f32, alive (Q, C) int32, counts (Q, 2L) int32):
    the final calibrated estimates, the post-level-(L−1) survivor mask,
    and per-level survivor counts (total, then delta-split) — everything
    the executor's ledger and rerank need, with no intermediate HBM
    round-trips.  C must be a multiple of block_c (ops.py pads) and
    ``k ≥ 1`` is the top-k pruning width.
    """
    l, nq, c, g = packed.shape
    assert c % block_c == 0, (c, block_c)
    nb = c // block_c
    kernel = functools.partial(_fused_kernel, num_levels=l, n_blocks=nb,
                               block_c=block_c, k=k, bound=bound)
    return pl.pallas_call(
        kernel,
        grid=(nq, l, nb),
        in_specs=_fused_in_specs(block_c, g),
        out_specs=[
            pl.BlockSpec((1, c), lambda qi, lv, ci: (qi, 0)),
            pl.BlockSpec((1, c), lambda qi, lv, ci: (qi, 0)),
            pl.BlockSpec((1, 2 * l), lambda qi, lv, ci: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, c), jnp.float32),
            jax.ShapeDtypeStruct((nq, c), jnp.int32),
            jax.ShapeDtypeStruct((nq, 2 * l), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((c,), jnp.float32),    # running estimate
            pltpu.VMEM((c,), jnp.float32),    # certified lower bound
            pltpu.VMEM((c,), jnp.float32),    # certified upper bound
            pltpu.VMEM((c,), jnp.float32),    # alive mask (0/1)
            pltpu.VMEM((c,), jnp.float32),    # delta-page flag (0/1)
            pltpu.SMEM((l,), jnp.float32),    # per-level pruning thresholds
        ],
        interpret=_resolve_interpret(interpret),
    )(packed, q_planes, scalars, level_scalars, params)


@functools.partial(jax.jit, static_argnames=("bound", "block_c",
                                             "interpret"))
def ternary_refine_fused_bounds(packed: jax.Array, q_planes: jax.Array,
                                scalars: jax.Array,
                                level_scalars: jax.Array,
                                params: jax.Array, *, bound: str,
                                block_c: int = 512,
                                interpret: bool | None = None
                                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sharded form of ``ternary_refine_fused``: same inputs and the same
    single-launch VMEM level stacking, returning (est (Q, C),
    lo (Q, L, C), hi (Q, L, C)) so the caller can pool each level's
    pruning threshold across a ``shard_map`` axis.  Bit-identical per
    candidate to the fused kernel (the arithmetic is shared)."""
    l, nq, c, g = packed.shape
    assert c % block_c == 0, (c, block_c)
    nb = c // block_c
    kernel = functools.partial(_fused_bounds_kernel, num_levels=l,
                               n_blocks=nb, block_c=block_c, bound=bound)
    return pl.pallas_call(
        kernel,
        grid=(nq, l, nb),
        in_specs=_fused_in_specs(block_c, g),
        out_specs=[
            pl.BlockSpec((1, block_c), lambda qi, lv, ci: (qi, ci)),
            pl.BlockSpec((1, 1, block_c), lambda qi, lv, ci: (qi, lv, ci)),
            pl.BlockSpec((1, 1, block_c), lambda qi, lv, ci: (qi, lv, ci)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, c), jnp.float32),
            jax.ShapeDtypeStruct((nq, l, c), jnp.float32),
            jax.ShapeDtypeStruct((nq, l, c), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((c,), jnp.float32),    # running estimate
        ],
        interpret=_resolve_interpret(interpret),
    )(packed, q_planes, scalars, level_scalars, params)
