"""PQ asymmetric-distance (ADC) Pallas kernel.

GPU ADC is a table-gather per subspace; TPU has no fast per-lane gather,
so we ADAPT: the lookup becomes a one-hot × LUT contraction that the MXU
executes as a matmul (hardware adaptation note in DESIGN.md §2).  For one
candidate block:

    onehot (BC, M·K) @ lut.flat (M·K,)  →  d̂₀ (BC,)

The one-hot is built in VMEM from a broadcasted iota comparison — never
touches HBM.  K=256, M≤64 keeps the block working set ≤ a few MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_kernel(codes_ref, lut_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)            # (BC, M)
    lut = lut_ref[...]                                  # (M, K)
    bc, m = codes.shape
    k = lut.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bc, m, k), 2)
    onehot = (iota == codes[:, :, None]).astype(jnp.float32)
    d = jnp.dot(onehot.reshape(bc, m * k), lut.reshape(m * k),
                preferred_element_type=jnp.float32)     # MXU matvec
    out_ref[:, 0] = d


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def pq_adc(codes: jax.Array, lut: jax.Array, *, block_c: int = 128,
           interpret: bool = True) -> jax.Array:
    """codes (C, M) uint8, lut (M, K) f32 → distances (C,) f32.

    C must be a multiple of block_c (ops.py pads).  VMEM: the (BC, M, K)
    one-hot at BC=128, M=16, K=256 is 2 MiB — sized for double buffering.
    """
    c, m = codes.shape
    k = lut.shape[1]
    assert c % block_c == 0, (c, block_c)
    out = pl.pallas_call(
        _adc_kernel,
        grid=(c // block_c,),
        in_specs=[
            pl.BlockSpec((block_c, m), lambda i: (i, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 1), jnp.float32),
        interpret=interpret,
    )(codes, lut)
    return out[:, 0]
