"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors a kernel in this package exactly (same math, same
planar packing layout) so tests can assert_allclose kernel-vs-ref across
shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_POW3 = (1, 3, 9, 27, 81)
TRITS_PER_BYTE = 5


def make_query_planes(q: jax.Array, g: int) -> jax.Array:
    """Arrange query dims into the (5, G) digit-plane layout: byte g digit i
    holds dim 5g+i (the paper's §III-D packing order)."""
    d = q.shape[-1]
    pad = g * TRITS_PER_BYTE - d
    qp = jnp.pad(q, (0, pad))
    return qp.reshape(g, TRITS_PER_BYTE).T            # (5, G)


def ternary_refine_ref(packed: jax.Array, q: jax.Array, d0: jax.Array,
                       delta_sq: jax.Array, cross: jax.Array,
                       norm: jax.Array, rho: jax.Array,
                       w: jax.Array, bias: jax.Array) -> jax.Array:
    """Oracle for the fused refine kernel.

    packed (C, G) uint8, q (D,), per-record scalars (C,), calibration
    w (4,) + bias.  Returns (C, 3): [est_calibrated, est_raw, margin].
    """
    from repro.core.packing import unpack_ternary

    d = q.shape[-1]
    code = unpack_ternary(packed, d).astype(jnp.float32)   # (C, D)
    qn = jnp.linalg.norm(q)
    k = jnp.sum(jnp.abs(code), axis=-1)
    align = (code @ q) / jnp.sqrt(jnp.maximum(k, 1.0))     # Σc·q/√k
    e_align = align / jnp.maximum(qn, 1e-30)               # ⟨e_q, e_code⟩
    d_ip = -2.0 * norm * rho * align
    est = (w[0] * d0 + w[1] * d_ip + w[2] * delta_sq + w[3] * cross + bias)
    est_raw = d0 + delta_sq + 2.0 * cross + d_ip
    margin = (2.0 * qn * norm
              * jnp.sqrt(jnp.clip(1.0 - e_align * e_align, 0.0, 1.0))
              * jnp.sqrt(jnp.clip(1.0 - rho * rho, 0.0, 1.0)))
    return jnp.stack([est, est_raw, margin], axis=-1)


def pq_adc_ref(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """Oracle for the ADC kernel: codes (C, M) uint8, lut (M, K) f32 → (C,).
    d(c) = Σ_m lut[m, codes[c, m]]."""
    idx = codes.astype(jnp.int32)
    part = jax.vmap(lambda t, i: t[i], in_axes=(0, 1), out_axes=1)(lut, idx)
    return jnp.sum(part, axis=-1)


def ternary_unpack_ref(packed: jax.Array, d: int) -> jax.Array:
    """Oracle for the standalone unpack kernel (int8 trits)."""
    from repro.core.packing import unpack_ternary

    return unpack_ternary(packed, d)
