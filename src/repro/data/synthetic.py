"""Synthetic embedding datasets standing in for Wiki-88M / LAION-100M.

The container is offline, so we generate clustered embeddings that match the
statistics that matter for ANNS behaviour: a Gaussian-mixture cluster
structure (so IVF lists are meaningful), anisotropic within-cluster spread
(heavy leading directions, like SBERT/CLIP embeddings after whitening-free
use), and near-unit norms.  Queries are drawn near database points
(in-distribution) plus a fraction of off-distribution noise.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Dataset(NamedTuple):
    x: jax.Array          # (N, D) database vectors
    queries: jax.Array    # (Q, D)
    gt: jax.Array         # (Q, k_gt) exact top-k ids (brute force)


def make_embeddings(key: jax.Array, n: int, d: int, *, clusters: int = 64,
                    spread: float = 0.35, decay: float = 0.7) -> jax.Array:
    """Clustered, anisotropic, ~unit-norm embeddings."""
    k_cent, k_assign, k_noise = jax.random.split(key, 3)
    centers = jax.random.normal(k_cent, (clusters, d))
    centers = centers / jnp.linalg.norm(centers, axis=-1, keepdims=True)
    ids = jax.random.randint(k_assign, (n,), 0, clusters)
    # anisotropic spread: per-dim scale decays (heavy leading dims)
    scales = decay ** (jnp.arange(d) / jnp.maximum(d / 16.0, 1.0))
    noise = jax.random.normal(k_noise, (n, d)) * scales[None, :] * spread
    x = centers[ids] + noise
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def brute_force_topk(x: jax.Array, queries: jax.Array, k: int,
                     *, block: int = 256) -> jax.Array:
    """Exact top-k under L2 (blocked over queries to bound memory)."""
    x_sq = jnp.sum(x * x, axis=-1)

    def one_block(qb):
        d = x_sq[None, :] - 2.0 * (qb @ x.T)   # + ||q||² (rank-invariant)
        _, idx = jax.lax.top_k(-d, k)
        return idx

    blocks = [one_block(queries[i:i + block])
              for i in range(0, queries.shape[0], block)]
    return jnp.concatenate(blocks, axis=0)


def make_dataset(key: jax.Array, *, n: int = 20_000, d: int = 128,
                 n_queries: int = 128, k_gt: int = 100,
                 clusters: int = 64, query_noise: float = 0.25) -> Dataset:
    """Full dataset with exact ground truth for recall evaluation."""
    k_x, k_pick, k_qn = jax.random.split(key, 3)
    x = make_embeddings(k_x, n, d, clusters=clusters)
    pick = jax.random.randint(k_pick, (n_queries,), 0, n)
    q = x[pick] + query_noise * jax.random.normal(k_qn, (n_queries, d)) \
        / jnp.sqrt(d)
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    gt = brute_force_topk(x, q, k_gt)
    return Dataset(x=x, queries=q, gt=gt)


def make_token_batch(key: jax.Array, batch: int, seq_len: int,
                     vocab: int) -> dict[str, jax.Array]:
    """Synthetic LM training batch (tokens + next-token labels)."""
    toks = jax.random.randint(key, (batch, seq_len + 1), 0, vocab,
                              dtype=jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
