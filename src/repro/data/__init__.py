from repro.data.synthetic import (Dataset, brute_force_topk, make_dataset,
                                  make_embeddings, make_token_batch)

__all__ = ["Dataset", "brute_force_topk", "make_dataset", "make_embeddings",
           "make_token_batch"]
