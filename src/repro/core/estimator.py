"""Progressive distance estimation with early termination (FaTRQ §III/§IV).

Pipeline per candidate batch:
  level 0: coarse ADC distance d̂₀ (already computed by the front stage —
           only 4 bytes/candidate cross the fast↔far boundary, per §IV)
  level 1: + precomputed scalars (first-order, zero I/O)
  level 2: + ternary residual estimate of −2⟨q,δ⟩ streamed from far memory
  ...      deeper TRQ levels, each tightening the estimate
  final:   survivors fetch full vectors ("SSD") for exact rerank.

Early termination: a candidate is dropped once it is *provably* outside the
running top-k.  Two bounds:

* ``cauchy`` (provable, needs per-record rho ∈ +4B):  from Eq. (1),
    ⟨e_q,e_δ⟩ = ⟨e_q,e_c⟩·rho + ||e_q − ⟨e_q,e_c⟩e_c||·⟨e_⊥,e_δ⟩
  and |⟨e_⊥,e_δ⟩| ≤ sqrt(1 − rho²) exactly (Cauchy–Schwarz in the plane),
  so  |⟨q,δ⟩ − est| ≤ ||q||·||δ||·sqrt(1−⟨e_q,e_c⟩²)·sqrt(1−rho²).
* ``quantile`` (paper-faithful storage): margin = z · resid_std from the
  calibration model; "provably" holds with calibrated confidence.

TPU adaptation: the paper's per-candidate serial early-exit becomes batched
level-wise pruning — score a whole block at level ℓ, keep a mask of
survivors, and only survivors contribute far-memory traffic at level ℓ+1.
(SIMD lanes cannot branch individually; the traffic model accounts for the
mask, and the Pallas kernel skips fully-pruned blocks.)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import calibration as calib
from repro.core.decomposition import RecordScalars
from repro.core.ternary import ternary_inner


class ProgressiveState(NamedTuple):
    """State carried across refinement levels for one query."""

    est: jax.Array        # (C,) current distance estimate per candidate
    lo: jax.Array         # (C,) certified lower bound
    alive: jax.Array      # (C,) bool — still a top-k contender
    tau: jax.Array        # ()   current top-k threshold (kth best upper bound)


def residual_ip_estimate(q: jax.Array, codes: jax.Array, norms: jax.Array,
                         rho: jax.Array | None = None) -> jax.Array:
    """Estimate −2⟨q, δ⟩ from ternary codes.

    est(⟨q,δ⟩) = ||q||·||δ||·⟨e_q, e_code⟩·rho  (rho→E[rho] if not stored;
    the calibration weight on this feature absorbs any constant factor).

    q: (D,), codes: (C, D) int8, norms: (C,) = ||δ||.
    """
    qn = jnp.linalg.norm(q)
    e_q = q / jnp.maximum(qn, 1e-30)
    align = ternary_inner(codes, e_q)          # ⟨e_q, e_code⟩, (C,)
    scale = rho if rho is not None else 1.0
    return -2.0 * qn * norms * align * scale


def cauchy_margin(q: jax.Array, codes: jax.Array, norms: jax.Array,
                  rho: jax.Array) -> jax.Array:
    """Provable half-width of −2⟨q,δ⟩ around its estimate (see module doc)."""
    qn = jnp.linalg.norm(q)
    e_q = q / jnp.maximum(qn, 1e-30)
    align = ternary_inner(codes, e_q)
    orth_q = jnp.sqrt(jnp.clip(1.0 - align * align, 0.0, 1.0))
    orth_d = jnp.sqrt(jnp.clip(1.0 - rho * rho, 0.0, 1.0))
    return 2.0 * qn * norms * orth_q * orth_d


def pooled_k_smallest(values: jax.Array, k: int,
                      axis_name: str | None = None) -> jax.Array:
    """kth smallest of ``values`` along the last axis, pooled globally.

    With ``axis_name`` (inside ``shard_map``) each shard contributes its
    ``min(k, local)`` smallest values, an all-gather pools them along the
    last axis, and the kth smallest of the pool is the EXACT global kth
    smallest (any global top-k member is in its shard's local top-k).
    The single implementation behind every sharded threshold — top-k
    pruning and the SSD rerank budget — so the cuts cannot drift apart.
    Leading axes are batched; +inf entries encode masked-out values.
    """
    kk = min(k, values.shape[-1])
    neg_top, _ = jax.lax.top_k(-values, kk)
    if axis_name is not None:
        pool = jax.lax.all_gather(neg_top, axis_name,
                                  axis=values.ndim - 1, tiled=True)
        neg_top, _ = jax.lax.top_k(pool, min(k, pool.shape[-1]))
    return -neg_top[..., -1]


def topk_threshold(estimates: jax.Array, alive: jax.Array, k: int,
                   axis_name: str | None = None) -> jax.Array:
    """kth-smallest upper estimate among alive candidates (τ for pruning).

    With ``axis_name`` (inside ``shard_map``) the threshold is global —
    see ``pooled_k_smallest`` — so sharded pruning keeps the same survivor
    set as an unsharded run.
    """
    masked = jnp.where(alive, estimates, jnp.inf)
    return pooled_k_smallest(masked, k, axis_name)


def refine_level(q: jax.Array, d0: jax.Array, scalars: RecordScalars,
                 codes: jax.Array, model: calib.CalibrationModel,
                 *, k: int, bound: str = "cauchy", z: float = 3.0,
                 prev_alive: jax.Array | None = None,
                 axis_name: str | None = None) -> ProgressiveState:
    """One FaTRQ refinement level over a candidate batch (single query).

    Returns estimates, certified lower bounds, the survivor mask after
    pruning against the updated top-k threshold, and the threshold itself.
    ``axis_name`` makes the threshold global across a shard_map axis (see
    ``topk_threshold``).
    """
    c = d0.shape[0]
    if prev_alive is None:
        prev_alive = jnp.ones((c,), bool)

    d_ip = residual_ip_estimate(q, codes, scalars.norm, scalars.rho)
    feats = calib.build_features(d0, d_ip, scalars.delta_sq, scalars.cross)
    # Calibrated estimate: used for RANKING (the FaTRQ queue order).
    est = calib.predict(model, feats)

    if bound == "cauchy":
        # Certified interval centered on the UNCALIBRATED decomposition
        # identity d̂ = d̂₀ + ||δ||² + 2⟨x_c,δ⟩ + d̂_ip, where the only error
        # is the residual inner-product term and |err| ≤ cauchy_margin holds
        # exactly (Cauchy–Schwarz) — pruning against it is provably sound.
        est_raw = d0 + scalars.delta_sq + 2.0 * scalars.cross + d_ip
        margin = cauchy_margin(q, codes, scalars.norm, scalars.rho)
        lo = est_raw - margin
        hi = est_raw + margin
    elif bound == "quantile":
        margin = z * model.resid_std
        lo = est - margin
        hi = est + margin
    else:
        raise ValueError(f"unknown bound {bound!r}")

    tau = topk_threshold(hi, prev_alive, k, axis_name)
    alive = prev_alive & (lo <= tau)
    return ProgressiveState(est=est, lo=lo, alive=alive, tau=tau)


def refine_batch(q: jax.Array, d0: jax.Array, scalars: RecordScalars,
                 codes: jax.Array, model: calib.CalibrationModel,
                 *, k: int, bound: str = "cauchy", z: float = 3.0
                 ) -> ProgressiveState:
    """Single-level convenience wrapper (the paper's second-order operating
    point). Multi-level stacking lives in trq.py."""
    return refine_level(q, d0, scalars, codes, model, k=k, bound=bound, z=z)
