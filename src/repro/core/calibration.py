"""Offline linear calibration of the refinement estimator (FaTRQ §III-E).

Recall is decided by ranking *near the top-k boundary*, not by global MSE.
FaTRQ fits ``Ŵ = argmin_W ||D − A W||²`` by OLS on a small calibration set
(~0.3% of records), where per (query, record) pair

    A = [ d̂₀,  d̂_ip,  ||δ||²,  ⟨x_c, δ⟩ ]

with d̂_ip the ternary estimate of −2⟨q, δ⟩ and D the true squared distance.
Calibration pairs come from the index itself (same inverted list for IVF,
graph neighbors for CAGRA) — no exact kNN needed.

With an exact residual inner product the identity weights are
``W* = [1, 1, 1, 2]`` (see decomposition.py), so the learned W also absorbs
the systematic shrinkage E[⟨e_code, e_δ⟩] of the ternary estimate.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CalibrationModel(NamedTuple):
    w: jax.Array          # (F,) or (F+1,) with bias
    bias: jax.Array       # scalar
    resid_std: jax.Array  # scalar — std of OLS residuals, used as the
                          # calibrated pruning margin (quantile bound).


def build_features(d0: jax.Array, d_ip: jax.Array, delta_sq: jax.Array,
                   cross: jax.Array) -> jax.Array:
    """Stack the paper's 4 features on a new trailing axis."""
    return jnp.stack([d0, d_ip, delta_sq, cross], axis=-1)


def fit(features: jax.Array, target: jax.Array, *, ridge: float = 1e-6
        ) -> CalibrationModel:
    """OLS (tiny ridge for conditioning) with intercept. features (N,F)."""
    n = features.shape[0]
    a = jnp.concatenate([features, jnp.ones((n, 1), features.dtype)], axis=1)
    gram = a.T @ a + ridge * jnp.eye(a.shape[1], dtype=a.dtype)
    coef = jnp.linalg.solve(gram, a.T @ target)
    pred = a @ coef
    resid_std = jnp.std(target - pred)
    return CalibrationModel(w=coef[:-1], bias=coef[-1], resid_std=resid_std)


def predict(model: CalibrationModel, features: jax.Array) -> jax.Array:
    """A·Ŵ + b — the lightweight query-time computation."""
    return features @ model.w + model.bias


def identity_model(dtype=jnp.float32) -> CalibrationModel:
    """W* = [1,1,1,2], b=0 — exact when d̂_ip is exact (test invariant)."""
    return CalibrationModel(w=jnp.asarray([1.0, 1.0, 1.0, 2.0], dtype),
                            bias=jnp.asarray(0.0, dtype),
                            resid_std=jnp.asarray(0.0, dtype))
