"""Tiered Residual Quantization — the paper's top-level artifact.

Encodes a database against its coarse (PQ) reconstructions into L stacked
ternary levels + per-record scalars, lays the codes out for far memory
(packed base-3), and answers progressive distance queries.

Level stacking: level ℓ encodes the residual left after projecting out the
previous level's approximation (``reconstruct`` in ternary.py), so estimates
tighten monotonically in expectation and the format is "naturally stackable"
(§III-A).  The paper's operating point is L=1 (second-order estimation).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import calibration as calib
from repro.core import packing
from repro.core.decomposition import RecordScalars, compute_scalars
from repro.core.estimator import (ProgressiveState, cauchy_margin,
                                  refine_level, residual_ip_estimate,
                                  topk_threshold)
from repro.core.ternary import TernaryCode, reconstruct, ternary_encode


@partial(jax.tree_util.register_dataclass,
         data_fields=("packed", "proj", "norm", "rho"), meta_fields=())
@dataclass(frozen=True)
class TRQLevel:
    """One far-memory level: packed codes + per-level scalars (all device
    arrays; (N, G) uint8 and (N,) f32)."""

    packed: jax.Array       # (N, ceil(D/5)) uint8 — far-memory resident
    proj: jax.Array         # (N,) ⟨δ_ℓ, e_code⟩ = ||δ_ℓ||·rho_ℓ
    norm: jax.Array         # (N,) ||δ_ℓ||
    rho: jax.Array          # (N,) ⟨e_δℓ, e_code⟩


@partial(jax.tree_util.register_dataclass,
         data_fields=("levels", "scalars", "model"), meta_fields=("dim",))
@dataclass(frozen=True)
class TRQCodes:
    """Full FaTRQ encoding of a database."""

    dim: int
    levels: tuple[TRQLevel, ...]
    scalars: RecordScalars          # level-0 metadata: ||δ||², ⟨x_c,δ⟩, rho, ||δ||
    model: calib.CalibrationModel   # calibrated estimator weights

    @property
    def num_levels(self) -> int:
        return len(self.levels)

    def bytes_per_record(self, *, paper_layout: bool = True) -> int:
        """Far-memory footprint. paper_layout: 2 scalars (8 B) + packed code
        per level; otherwise include rho (+4 B/level) for provable bounds."""
        per_level = packing.packed_size(self.dim)
        scalars = 8 if paper_layout else 12
        return self.num_levels * per_level + scalars


def encode_database(x: jax.Array, x_c: jax.Array, *, num_levels: int = 1
                    ) -> tuple[TRQCodes, list[TernaryCode]]:
    """Encode records ``x (N, D)`` against coarse reconstructions ``x_c``.

    Returns the packed TRQCodes (with an identity calibration model — call
    ``calibrate`` to fit) and the raw per-level TernaryCodes (test hooks).
    """
    delta = x - x_c
    levels: list[TRQLevel] = []
    raw: list[TernaryCode] = []
    resid = delta
    for _ in range(num_levels):
        tc = ternary_encode(resid)
        raw.append(tc)
        levels.append(TRQLevel(
            packed=packing.pack_ternary(tc.code),
            proj=(tc.norm * tc.rho).astype(jnp.float32),
            norm=tc.norm,
            rho=tc.rho,
        ))
        resid = resid - reconstruct(tc)
    scalars = compute_scalars(x, x_c, rho=raw[0].rho)
    codes = TRQCodes(dim=x.shape[-1], levels=tuple(levels), scalars=scalars,
                     model=calib.identity_model())
    return codes, raw


def encode_rows(x_new: jax.Array, x_c_new: jax.Array, *, num_levels: int = 1,
                model: calib.CalibrationModel | None = None) -> TRQCodes:
    """Incremental encode: TRQ codes for ``x_new`` (B, D) ONLY.

    Every per-record quantity (``ternary_encode`` trits, level scalars,
    ``compute_scalars``) is row-independent, so encoding a batch of new
    rows in isolation is bit-identical to what a full ``encode_database``
    over the grown database would produce for those rows — the streaming
    subsystem (anns/streaming.py) appends the result with ``write_rows``
    without touching existing rows.  ``model`` carries the already-fitted
    calibration over (calibration is a property of the quantizers, not of
    individual rows; default: identity).
    """
    codes, _ = encode_database(x_new, x_c_new, num_levels=num_levels)
    if model is not None:
        codes = TRQCodes(dim=codes.dim, levels=codes.levels,
                         scalars=codes.scalars, model=model)
    return codes


def write_rows(dst: TRQCodes, src: TRQCodes, start: int) -> TRQCodes:
    """Write ``src``'s rows into ``dst`` at ``start`` (functional append).

    Applies ``lax.dynamic_update_slice`` to every per-record leaf (packed
    codes + level scalars + record scalars); the calibration model and dim
    come from ``dst``.  ``dst`` must have capacity ≥ start + len(src) —
    the streaming row store over-allocates and grows host-side.
    """
    if dst.num_levels != src.num_levels or dst.dim != src.dim:
        raise ValueError("write_rows: level/dim mismatch between dst and src")

    def upd(d, s):
        return jax.lax.dynamic_update_slice(
            d, s.astype(d.dtype), (start,) + (0,) * (d.ndim - 1))

    levels = tuple(jax.tree.map(upd, dl, sl)
                   for dl, sl in zip(dst.levels, src.levels))
    scalars = jax.tree.map(upd, dst.scalars, src.scalars)
    return TRQCodes(dim=dst.dim, levels=levels, scalars=scalars,
                    model=dst.model)


def gather_rows(codes: TRQCodes, idx: jax.Array) -> TRQCodes:
    """Row-gather every per-record leaf (packed codes, level scalars,
    record scalars) at ``idx``; dim + calibration model pass through.
    Compaction/snapshotting in the streaming subsystem moves packed codes
    with this — codes are centroid-relative, so moving a row never needs a
    re-encode."""
    g = lambda a: a[idx]                                      # noqa: E731
    return TRQCodes(dim=codes.dim,
                    levels=tuple(jax.tree.map(g, lv) for lv in codes.levels),
                    scalars=jax.tree.map(g, codes.scalars),
                    model=codes.model)


def unpack_level(codes: TRQCodes, level: int, idx: jax.Array | None = None
                 ) -> jax.Array:
    """Materialize int8 trits for (a subset of) records at one level."""
    packed = codes.levels[level].packed
    if idx is not None:
        packed = packed[idx]
    return packing.unpack_ternary(packed, codes.dim)


def estimate_q_dot_delta(q: jax.Array, codes: TRQCodes,
                         idx: jax.Array | None = None,
                         *, through_level: int | None = None) -> jax.Array:
    """Σ_ℓ ⟨δ,e_cℓ⟩·⟨q,e_cℓ⟩ — the stacked estimate of ⟨q, δ⟩.

    Each level contributes its projection coefficient times the query
    alignment with its code direction; exact as L→D.
    """
    through = codes.num_levels if through_level is None else through_level
    total = 0.0
    for lv in range(through):
        level = codes.levels[lv]
        trits = unpack_level(codes, lv, idx)
        from repro.core.ternary import ternary_inner
        align = ternary_inner(trits, q)           # ⟨q, e_code⟩ (already /√k)
        proj = level.proj if idx is None else level.proj[idx]
        total = total + proj * align
    return total


def calibrate(codes: TRQCodes, q_samples: jax.Array, x: jax.Array,
              x_c: jax.Array, pair_idx: jax.Array) -> TRQCodes:
    """Fit the OLS calibration model on (query, neighbor) pairs.

    q_samples (P, D): calibration queries; pair_idx (P,): the database row
    each query is paired with (index-adjacent neighbors, §III-E — same
    inverted list / graph neighbors; no exact kNN required).
    """
    xi = x[pair_idx]
    xci = x_c[pair_idx]
    d0 = jnp.sum((q_samples - xci) ** 2, axis=-1)
    true_d = jnp.sum((q_samples - xi) ** 2, axis=-1)

    sc = codes.scalars
    delta_sq = sc.delta_sq[pair_idx]
    cross = sc.cross[pair_idx]
    norms = sc.norm[pair_idx]
    rho = sc.rho[pair_idx]

    trits = unpack_level(codes, 0, pair_idx)
    d_ip = jax.vmap(
        lambda qq, cc, nn, rr: residual_ip_estimate(qq, cc[None], nn[None],
                                                    rr[None])[0]
    )(q_samples, trits, norms, rho)

    feats = calib.build_features(d0, d_ip, delta_sq, cross)
    model = calib.fit(feats, true_d)
    return TRQCodes(dim=codes.dim, levels=codes.levels, scalars=codes.scalars,
                    model=model)


def progressive_search(q: jax.Array, d0: jax.Array, codes: TRQCodes,
                       cand_idx: jax.Array, *, k: int,
                       bound: str = "cauchy", z: float = 3.0,
                       axis_name: str | None = None,
                       collect_level_alive: bool = False):
    """Run all TRQ levels over a candidate list for one query, pruning
    between levels.  Returns the final ProgressiveState (estimates + alive
    mask); the pipeline layer turns `alive` into SSD fetches.

    ``axis_name``: inside ``shard_map``, compute every pruning threshold
    globally across the named mesh axis (see ``estimator.topk_threshold``)
    so per-shard survivor masks match an unsharded run exactly.
    ``collect_level_alive``: also return the tuple of alive masks after each
    level — level ℓ+1's far-memory traffic is charged to survivors of level
    ℓ, so the executor needs the whole chain, not just the final mask.
    """
    sc = codes.scalars
    scalars = RecordScalars(delta_sq=sc.delta_sq[cand_idx],
                            cross=sc.cross[cand_idx],
                            rho=sc.rho[cand_idx],
                            norm=sc.norm[cand_idx])
    state = None
    alive = jnp.ones(cand_idx.shape, bool)
    # Level 0 (paper's second-order estimate), then deeper levels tighten.
    trits = unpack_level(codes, 0, cand_idx)
    state = refine_level(q, d0, scalars, trits, codes.model, k=k,
                         bound=bound, z=z, prev_alive=alive,
                         axis_name=axis_name)
    level_alive = [state.alive]
    if codes.num_levels > 1:
        # Deeper levels: each adds −2·⟨q, δ̂_ℓ⟩ with δ̂_ℓ = proj_ℓ·e_code_ℓ,
        # and the certified margin shrinks to the norm of what remains.
        from repro.core.ternary import ternary_inner
        qn = jnp.linalg.norm(q)
        est = state.est
        for lv in range(1, codes.num_levels):
            level = codes.levels[lv]
            trits = unpack_level(codes, lv, cand_idx)
            align = ternary_inner(trits, q)               # ⟨q, e_code_ℓ⟩
            est = est - 2.0 * level.proj[cand_idx] * align
            # remaining residual after level ℓ: ||δ_ℓ||·sqrt(1 − rho_ℓ²)
            rem = level.norm[cand_idx] * jnp.sqrt(
                jnp.clip(1.0 - level.rho[cand_idx] ** 2, 0.0, 1.0))
            margin = 2.0 * qn * rem + codes.model.resid_std
            hi = est + margin
            tau = topk_threshold(hi, state.alive, k, axis_name)
            alive = state.alive & (est - margin <= tau)
            state = ProgressiveState(est=est, lo=est - margin,
                                     alive=alive, tau=tau)
            level_alive.append(alive)
    if collect_level_alive:
        return state, tuple(level_alive)
    return state
