"""L2 distance decomposition (FaTRQ §III-A).

    ||x - q||² = ||q - x_c||² + ||δ||² + 2⟨x_c, δ⟩ − 2⟨q, δ⟩ ,   δ = x − x_c

The first term is the coarse (PQ/ADC) distance d̂₀; ``||δ||²`` and
``⟨x_c, δ⟩`` are per-record scalars precomputed offline; only ``⟨q, δ⟩``
needs query-time estimation from the ternary residual code.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RecordScalars(NamedTuple):
    """The paper's 8-byte per-record metadata (+ optional rho, see below)."""

    delta_sq: jax.Array     # ||δ||²   (f32)
    cross: jax.Array        # ⟨x_c, δ⟩ (f32)
    # Optional extras (not in the paper's 8B layout; used by the provable
    # pruning bound and the multi-level stack):
    rho: jax.Array          # ⟨e_δ, e_code⟩
    norm: jax.Array         # ||δ||


def compute_scalars(x: jax.Array, x_c: jax.Array, rho: jax.Array | None = None
                    ) -> RecordScalars:
    """Precompute per-record scalars from the original vector and its coarse
    reconstruction. Batched over leading axes."""
    delta = x - x_c
    delta_sq = jnp.sum(delta * delta, axis=-1)
    cross = jnp.sum(x_c * delta, axis=-1)
    norm = jnp.sqrt(delta_sq)
    if rho is None:
        rho = jnp.zeros_like(norm)
    return RecordScalars(delta_sq=delta_sq.astype(jnp.float32),
                         cross=cross.astype(jnp.float32),
                         rho=rho.astype(jnp.float32),
                         norm=norm.astype(jnp.float32))


def exact_distance_sq(q: jax.Array, x: jax.Array) -> jax.Array:
    """||x − q||² on the trailing axis (ground truth / final rerank)."""
    diff = x - q
    return jnp.sum(diff * diff, axis=-1)


def first_order(d0: jax.Array, scalars: RecordScalars) -> jax.Array:
    """d̂₁ = d̂₀ + ||δ||² + 2⟨x_c,δ⟩ — zero extra query-time I/O.

    Note the paper first presents d̂₁ = d̂₀ + ||δ||² (treating the inner
    product as zero-mean); including the precomputed cross term is free and
    strictly tighter, which is what the final estimator (§III-E) does.
    """
    return d0 + scalars.delta_sq + 2.0 * scalars.cross


def decomposed_distance_sq(d0: jax.Array, scalars: RecordScalars,
                           q_dot_delta: jax.Array) -> jax.Array:
    """Exact identity given the true ⟨q, δ⟩ (used by tests)."""
    return d0 + scalars.delta_sq + 2.0 * scalars.cross - 2.0 * q_dot_delta
