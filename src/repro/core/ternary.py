"""Optimal ternary residual-direction encoding (FaTRQ §III-C).

Given a residual vector ``delta``, find the codeword ``c ∈ {-1,0,1}^D``
whose normalization ``c/||c||`` maximizes the inner product with
``e_delta = delta/||delta||``.  The paper's key observation: the optimum
keeps the sign of the ``k*`` largest-magnitude components and zeros the
rest, where ``k* = argmax_k S_k/sqrt(k)`` over the descending-sorted
magnitudes' prefix sums ``S_k``.  Exact optimum in O(D log D), no 3^D
enumeration.

Everything here is pure jnp, jit- and vmap-compatible, and operates on the
trailing axis so batched inputs ``(..., D)`` work directly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class TernaryCode(NamedTuple):
    """A ternary codeword plus the per-record scalars FaTRQ stores.

    Attributes:
      code:  int8 ``(..., D)`` with values in {-1, 0, +1}.
      k:     int32 ``(...,)`` number of nonzeros (``||code||² = k``).
      rho:   float32 ``(...,)`` alignment ``⟨e_delta, e_code⟩ ∈ [0, 1]``.
             Not part of the paper's 8-byte metadata (the calibration model
             absorbs E[rho]); kept optionally for the provable Cauchy–Schwarz
             pruning bound (see estimator.py).
      norm:  float32 ``(...,)`` the residual L2 norm ``||delta||``.
    """

    code: jax.Array
    k: jax.Array
    rho: jax.Array
    norm: jax.Array


def optimal_k(sorted_mags: jax.Array) -> tuple[jax.Array, jax.Array]:
    """``k* = argmax_k S_k / sqrt(k)`` for descending-sorted magnitudes.

    Args:
      sorted_mags: ``(..., D)`` non-negative, sorted descending on last axis.

    Returns:
      (k_star ``(...,)`` int32 in [1, D],
       score  ``(...,)`` the achieved ``S_k*/sqrt(k*) = ⟨e_code, e_delta⟩·||delta||``).
    """
    d = sorted_mags.shape[-1]
    csum = jnp.cumsum(sorted_mags, axis=-1)
    ks = jnp.arange(1, d + 1, dtype=sorted_mags.dtype)
    scores = csum / jnp.sqrt(ks)
    idx = jnp.argmax(scores, axis=-1)
    k_star = (idx + 1).astype(jnp.int32)
    best = jnp.take_along_axis(scores, idx[..., None], axis=-1)[..., 0]
    return k_star, best


def ternary_encode(delta: jax.Array) -> TernaryCode:
    """Encode residual(s) ``delta (..., D)`` into the optimal ternary code."""
    delta = jnp.asarray(delta)
    mags = jnp.abs(delta)
    # Descending sort of magnitudes → prefix-sum scan for k*.
    sorted_mags = -jnp.sort(-mags, axis=-1)
    k_star, _ = optimal_k(sorted_mags)

    # rank of each element under descending magnitude (ties broken by index,
    # deterministically — matches taking "the first k of the sorted list").
    order = jnp.argsort(-mags, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    mask = ranks < k_star[..., None]

    code = (jnp.sign(delta) * mask).astype(jnp.int8)
    # Guard sign(0)=0 inside the mask: a zero component contributes nothing
    # either way, but keep k consistent with the actual nonzero count.
    k = jnp.sum(jnp.abs(code).astype(jnp.int32), axis=-1)

    norm = jnp.linalg.norm(delta, axis=-1)
    # rho = <e_delta, code/sqrt(k)> = (Σ selected |delta_i|) / (||delta||·sqrt(k))
    sel_sum = jnp.sum(mags * mask, axis=-1)
    safe = jnp.maximum(norm * jnp.sqrt(jnp.maximum(k, 1).astype(delta.dtype)), 1e-30)
    rho = jnp.where(norm > 0, sel_sum / safe, 0.0)
    return TernaryCode(code=code, k=k, rho=rho.astype(jnp.float32),
                       norm=norm.astype(jnp.float32))


def ternary_decode_direction(code: jax.Array) -> jax.Array:
    """Normalized direction ``e_code = code / ||code||`` as float32."""
    c = code.astype(jnp.float32)
    k = jnp.sum(c * c, axis=-1, keepdims=True)
    return c / jnp.sqrt(jnp.maximum(k, 1.0))


def reconstruct(tc: TernaryCode) -> jax.Array:
    """Best L2 approximation of delta in span(e_code): ``||δ||·rho·e_code``.

    Used for stacking levels: the next level encodes ``delta - reconstruct``.
    """
    e = ternary_decode_direction(tc.code)
    return (tc.norm * tc.rho)[..., None] * e


def ternary_inner(code: jax.Array, q: jax.Array) -> jax.Array:
    """``⟨q, e_code⟩`` — the multiplication-free datapath of the paper.

    On TPU this lowers to a sign-select + reduction (or an MXU matmul when
    batched — see kernels/ternary_refine.py); here it is the reference form.
    ``code (..., D)`` int8, ``q`` broadcastable ``(..., D)``.
    """
    c = code.astype(q.dtype)
    k = jnp.sum(jnp.abs(c), axis=-1)
    raw = jnp.sum(c * q, axis=-1)
    return raw / jnp.sqrt(jnp.maximum(k, 1.0))


def brute_force_optimal(delta: jax.Array) -> jax.Array:
    """Exhaustive 3^D search (tiny D only) — test oracle for optimality."""
    import itertools

    import numpy as np

    delta = np.asarray(delta)
    d = delta.shape[-1]
    assert delta.ndim == 1 and d <= 12, "oracle is for tiny D"
    best, best_ip = None, -np.inf
    for c in itertools.product((-1, 0, 1), repeat=d):
        c = np.array(c, dtype=np.float64)
        k = (c != 0).sum()
        if k == 0:
            continue
        ip = float(c @ delta) / np.sqrt(k)
        if ip > best_ip:
            best_ip, best = ip, c
    return jnp.asarray(best, dtype=jnp.int8)
