"""FaTRQ core: ternary residual quantization + progressive distance estimation."""

from repro.core.calibration import CalibrationModel, fit, identity_model, predict
from repro.core.decomposition import (RecordScalars, compute_scalars,
                                      decomposed_distance_sq,
                                      exact_distance_sq, first_order)
from repro.core.estimator import (ProgressiveState, cauchy_margin,
                                  refine_batch, refine_level,
                                  residual_ip_estimate, topk_threshold)
from repro.core.packing import (pack_ternary, packed_size, storage_bytes,
                                unpack_ternary)
from repro.core.ternary import (TernaryCode, optimal_k, reconstruct,
                                ternary_decode_direction, ternary_encode,
                                ternary_inner)
from repro.core.trq import (TRQCodes, TRQLevel, calibrate, encode_database,
                            estimate_q_dot_delta, progressive_search,
                            unpack_level)

__all__ = [
    "CalibrationModel", "fit", "identity_model", "predict",
    "RecordScalars", "compute_scalars", "decomposed_distance_sq",
    "exact_distance_sq", "first_order",
    "ProgressiveState", "cauchy_margin", "refine_batch", "refine_level",
    "residual_ip_estimate", "topk_threshold",
    "pack_ternary", "packed_size", "storage_bytes", "unpack_ternary",
    "TernaryCode", "optimal_k", "reconstruct", "ternary_decode_direction",
    "ternary_encode", "ternary_inner",
    "TRQCodes", "TRQLevel", "calibrate", "encode_database",
    "estimate_q_dot_delta", "progressive_search", "unpack_level",
]
