"""Base-3 packing of ternary codes: 5 trits per byte (FaTRQ §III-D).

``y = Σ_{i=0..4} 3^i (x_i + 1)`` maps 5 values in {-1,0,1} to one byte in
[0, 242].  1.6 bits/dimension vs the 1.585-bit entropy bound.  768-D →
⌈768/5⌉ = 154 bytes (+8 bytes scalars = 162 B, the paper's number).

Pure jnp, trailing-axis semantics, jit/vmap-safe.  The Pallas unpack kernel
(kernels/ternary_pack.py) mirrors ``unpack_ternary`` with div/mod chains.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

TRITS_PER_BYTE = 5
_POW3 = (1, 3, 9, 27, 81)


def packed_size(d: int) -> int:
    """Bytes needed for a D-dimensional ternary code."""
    return -(-d // TRITS_PER_BYTE)


def pack_ternary(code: jax.Array) -> jax.Array:
    """Pack int8 trits in {-1,0,1} ``(..., D)`` → uint8 ``(..., ceil(D/5))``.

    Padding trits are 0 (encoded as digit 1), harmless on unpack+truncate.
    """
    d = code.shape[-1]
    g = packed_size(d)
    pad = g * TRITS_PER_BYTE - d
    digits = (code.astype(jnp.int32) + 1)
    if pad:
        pad_widths = [(0, 0)] * (code.ndim - 1) + [(0, pad)]
        digits = jnp.pad(digits, pad_widths, constant_values=1)
    digits = digits.reshape(*code.shape[:-1], g, TRITS_PER_BYTE)
    weights = jnp.asarray(_POW3, dtype=jnp.int32)
    return jnp.sum(digits * weights, axis=-1).astype(jnp.uint8)


def unpack_ternary(packed: jax.Array, d: int) -> jax.Array:
    """Unpack uint8 ``(..., G)`` → int8 trits ``(..., D)`` in {-1,0,1}."""
    y = packed.astype(jnp.int32)[..., None]  # (..., G, 1)
    weights = jnp.asarray(_POW3, dtype=jnp.int32)
    digits = (y // weights) % 3  # (..., G, 5)
    trits = digits.reshape(*packed.shape[:-1], packed.shape[-1] * TRITS_PER_BYTE)
    return (trits[..., :d] - 1).astype(jnp.int8)


def storage_bytes(d: int, *, n_scalars: int = 2, scalar_bytes: int = 4) -> int:
    """Per-record far-memory footprint (paper: 768 → 154 + 8 = 162 B)."""
    return packed_size(d) + n_scalars * scalar_bytes
