"""AdamW in pure JAX (pytree-structured, pjit-friendly).

States inherit the parameter sharding (same pytree structure), so FSDP
sharding of params automatically shards optimizer state — no special
handling needed at 1000-node scale.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def update(grads, state: AdamWState, params, *, lr: float = 3e-4,
           b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.1, grad_clip: float = 1.0
           ) -> tuple[dict, AdamWState]:
    step = state.step + 1
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu,
                      grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        return (p.astype(jnp.float32) - lr * (u + weight_decay *
                p.astype(jnp.float32))).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
