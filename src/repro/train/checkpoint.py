"""Checkpoint / restore with elastic resharding — the fault-tolerance
substrate.

Design for 1000+ nodes:
  * each process writes only its addressable shards (`save` iterates
    addressable_shards; on this 1-process container that is the whole
    array, on a real pod it is the local chunk) — no gather to host 0;
  * a JSON manifest stores the logical shapes/dtypes + step, never device
    topology, so a checkpoint written on N chips restores onto M chips:
    `restore` rebuilds each array with jnp + device_put under the *new*
    mesh/sharding (elastic scaling);
  * atomic rename (tmp dir → final) so a mid-write failure never corrupts
    the latest checkpoint; `latest_step` scans completed manifests only.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, jax.Array]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(ckpt_dir: str, step: int, tree, *, process_index: int | None = None
         ) -> str:
    """Write `tree` as step-<n>/ with per-leaf .npy + manifest.json."""
    pi = jax.process_index() if process_index is None else process_index
    final = os.path.join(ckpt_dir, f"step-{step:08d}")
    tmp = final + f".tmp{pi}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for i, (name, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][name] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step-") and not d.endswith(".tmp0") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("-")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *, shardings=None):
    """Restore into the structure of `like_tree`; `shardings` (same
    structure, of jax.sharding.Sharding) re-lays the arrays onto the
    CURRENT mesh — this is the elastic-rescale path."""
    path = os.path.join(ckpt_dir, f"step-{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    flat_sh = None
    if shardings is not None:
        flat_sh = [s for _, s in
                   jax.tree_util.tree_flatten_with_path(shardings)[0]]
    leaves = []
    for i, (kpath, leaf) in enumerate(flat_like):
        name = jax.tree_util.keystr(kpath)
        meta = manifest["leaves"][name]
        arr = np.load(os.path.join(path, meta["file"]))
        assert list(arr.shape) == list(leaf.shape), (name, arr.shape,
                                                     leaf.shape)
        out = jnp.asarray(arr, dtype=leaf.dtype)
        if flat_sh is not None:
            out = jax.device_put(out, flat_sh[i])
        leaves.append(out)
    return jax.tree_util.tree_unflatten(treedef, leaves)
