"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests/examples on the
1-device container):
  * periodic checkpointing (atomic, per-process shards) + deterministic
    resume from the latest manifest (checkpoint.py);
  * elastic restart: the checkpoint stores logical arrays, restore() lays
    them onto whatever mesh/sharding the relaunched job built;
  * straggler mitigation: per-step wall-time is tracked with an EWMA; a
    step exceeding `straggler_factor`× the EWMA is logged and counted —
    on a real fleet this signal feeds the scheduler's replace-node hook
    (`on_straggler` callback, pluggable);
  * data pipeline determinism: batch keys derive from the global step, so
    resumed runs replay the exact token stream (no double-consume);
  * loss-spike rejection (NaN/Inf or >spike_factor× EWMA loss → skip the
    update), the standard large-fleet guard against corrupt hosts.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.data import make_token_batch
from repro.models.model_zoo import ModelApi, loss_fn
from repro.train import checkpoint as ckpt
from repro.train import optimizer


@dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    straggler_factor: float = 3.0
    spike_factor: float = 10.0
    seed: int = 0


@dataclass
class TrainState:
    params: Any
    opt: optimizer.AdamWState
    step: int = 0
    losses: list = field(default_factory=list)
    stragglers: int = 0
    skipped: int = 0


def make_step_fn(api: ModelApi, tc: TrainConfig):
    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(api, p, batch))(params)
        new_params, new_opt = optimizer.update(grads, opt_state, params,
                                               lr=tc.lr)
        return loss, new_params, new_opt
    return step_fn


def train(api: ModelApi, tc: TrainConfig, *, resume: bool = True,
          on_straggler: Callable[[int, float], None] | None = None,
          extra_batch: Callable[[jax.Array], dict] | None = None
          ) -> TrainState:
    params = api.init(jax.random.PRNGKey(tc.seed))
    opt = optimizer.init(params)
    state = TrainState(params=params, opt=opt)

    if resume:
        latest = ckpt.latest_step(tc.ckpt_dir)
        if latest is not None:
            tree = {"params": state.params, "opt": state.opt}
            restored = ckpt.restore(tc.ckpt_dir, latest, tree)
            state.params, state.opt = restored["params"], restored["opt"]
            state.step = latest

    step_fn = make_step_fn(api, tc)
    ewma_t, ewma_loss = None, None
    first_step = state.step   # step 0 compiles — exclude from the EWMA
    while state.step < tc.steps:
        t0 = time.time()   # whole iteration: data pipeline + step
        key = jax.random.fold_in(jax.random.PRNGKey(tc.seed + 1), state.step)
        batch = make_token_batch(key, tc.batch, tc.seq_len, api.cfg.vocab)
        if extra_batch is not None:
            batch.update(extra_batch(key))
        loss, new_params, new_opt = step_fn(state.params, state.opt, batch)
        loss = float(loss)
        dt = time.time() - t0

        if ewma_t is not None and dt > tc.straggler_factor * ewma_t:
            state.stragglers += 1
            if on_straggler:
                on_straggler(state.step, dt)
        elif state.step > first_step:    # warmup step (compile) excluded
            ewma_t = dt if ewma_t is None else 0.9 * ewma_t + 0.1 * dt

        spike = (not jnp.isfinite(loss)) or (
            ewma_loss is not None and loss > tc.spike_factor *
            max(ewma_loss, 1e-6))
        if spike:
            state.skipped += 1          # reject the update, keep going
        else:
            state.params, state.opt = new_params, new_opt
            ewma_loss = loss if ewma_loss is None else \
                0.9 * ewma_loss + 0.1 * loss
            state.losses.append(loss)
        state.step += 1

        if tc.ckpt_every and state.step % tc.ckpt_every == 0:
            ckpt.save(tc.ckpt_dir, state.step,
                      {"params": state.params, "opt": state.opt})
    return state
