"""Gradient compression for cross-pod data parallelism.

At 1000+ nodes the `pod` axis crosses the DCN boundary, where gradient
all-reduce bandwidth — not compute — bounds step time.  We provide int8
quantized all-reduce with ERROR FEEDBACK (Seide et al.; 1-bit Adam
lineage): each step transmits int8 values + one f32 scale per tensor
(≈4× fewer bytes than f32, 2× fewer than bf16), and the local
quantization error is fed back into the next step so the compression
noise telescopes instead of accumulating.

Usage (inside shard_map over the dp axes):
    g_sum, new_err = compressed_psum(g + err, axis_names)
or at the optimizer boundary:
    grads, err = compress_grads(grads, err)        # pjit-friendly form
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jax.Array, err: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """One error-feedback compression round for a gradient leaf.

    Returns (what-the-wire-carries dequantized, new error residual)."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    sent = dequantize_int8(q, scale)
    return sent, target - sent


def compress_grads(grads, err_state):
    """Tree version.  err_state=None initializes zeros."""
    if err_state is None:
        err_state = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(compress_leaf, grads, err_state)
    sent = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return sent, new_err


def compressed_psum(x: jax.Array, axis_names, err: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """int8 all-reduce inside shard_map: scale agreed via psum-max, int8
    payload summed in int32 (no overflow for ≤2^23 participants)."""
    target = x.astype(jnp.float32) + err
    scale = jax.lax.pmax(jnp.max(jnp.abs(target)), axis_names) / 127.0
    scale = scale + 1e-30
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_names).astype(jnp.float32) * scale
    sent_local = q.astype(jnp.float32) * scale
    return total, target - sent_local


def wire_bytes(tree, *, compressed: bool) -> int:
    """Bytes a ring all-reduce moves per step (per hop, 2(n-1)/n ≈ 2×)."""
    total = 0
    for g in jax.tree.leaves(tree):
        n = 1
        for d in g.shape:
            n *= d
        total += n * (1 if compressed else 4) + (4 if compressed else 0)
    return 2 * total
