from repro.train import checkpoint, optimizer

__all__ = ["checkpoint", "optimizer"]
