"""Quantization substrate: k-means, PQ (coarse quantizer), SQ/RQ baselines."""

from repro.quant import pq, rq, sq
from repro.quant.kmeans import assign, kmeans, quantization_error

__all__ = ["pq", "rq", "sq", "assign", "kmeans", "quantization_error"]
