"""Classic residual quantization baseline (Liu et al. / Yuan & Liu).

L stages of PQ, each encoding the residual of the previous stage; decoding
sums all stage reconstructions (the non-progressive ADC of §II-B that FaTRQ
improves on: baselines decode *all* levels for *every* candidate).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.quant import pq


@dataclass(frozen=True)
class RQCodebook:
    stages: tuple[pq.PQCodebook, ...]


def train(key: jax.Array, x: jax.Array, m: int, k: int = 256,
          levels: int = 2, iters: int = 15) -> tuple[RQCodebook, jax.Array]:
    """Train L stacked PQ stages; returns codebook + final residual."""
    stages = []
    resid = x
    for lv in range(levels):
        cb = pq.train(jax.random.fold_in(key, lv), resid, m, k, iters)
        codes = pq.encode(cb, resid)
        resid = resid - pq.decode(cb, codes)
        stages.append(cb)
    return RQCodebook(stages=tuple(stages)), resid


def encode(rq: RQCodebook, x: jax.Array) -> jax.Array:
    """x (N, D) → codes (N, L, M) uint8."""
    out, resid = [], x
    for cb in rq.stages:
        c = pq.encode(cb, resid)
        resid = resid - pq.decode(cb, c)
        out.append(c)
    return jnp.stack(out, axis=1)


def decode(rq: RQCodebook, codes: jax.Array, *, through_level: int | None = None
           ) -> jax.Array:
    through = len(rq.stages) if through_level is None else through_level
    total = 0.0
    for lv in range(through):
        total = total + pq.decode(rq.stages[lv], codes[:, lv])
    return total


def adc_distances(rq: RQCodebook, q: jax.Array, codes: jax.Array) -> jax.Array:
    """Full (all-level) ADC — the baseline's wasteful always-decode path."""
    recon = decode(rq, codes)
    return jnp.sum((recon - q[None, :]) ** 2, axis=-1)
