"""jit-compiled Lloyd's k-means — the shared trainer for IVF coarse
centroids and PQ sub-codebooks.

Distance trick: argmin_c ||x−c||² = argmin_c (||c||² − 2x·c), so assignment
is one matmul (MXU-friendly) — no (N, K, D) intermediate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def assign(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """Nearest-centroid ids for x (N, D) against centroids (K, D)."""
    c_sq = jnp.sum(centroids * centroids, axis=-1)           # (K,)
    scores = x @ centroids.T                                  # (N, K) — MXU
    return jnp.argmin(c_sq[None, :] - 2.0 * scores, axis=-1)


def _update(x: jax.Array, ids: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Mean of members per centroid (segment-sum) + member counts."""
    one_hot = jax.nn.one_hot(ids, k, dtype=x.dtype)           # (N, K)
    counts = jnp.sum(one_hot, axis=0)                         # (K,)
    sums = one_hot.T @ x                                      # (K, D)
    means = sums / jnp.maximum(counts, 1.0)[:, None]
    return means, counts


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key: jax.Array, x: jax.Array, k: int, iters: int = 25) -> jax.Array:
    """Train k centroids on x (N, D); k-means++-lite init (random distinct
    samples) then `iters` Lloyd steps.  Empty clusters are re-seeded from the
    point currently farthest from its centroid."""
    n = x.shape[0]
    init_idx = jax.random.choice(key, n, (k,), replace=False)
    centroids = x[init_idx]

    def step(carry, _):
        cents = carry
        ids = assign(x, cents)
        means, counts = _update(x, ids, k)
        # re-seed empties at the worst-fit point
        d = jnp.sum((x - cents[ids]) ** 2, axis=-1)
        worst = x[jnp.argmax(d)]
        cents = jnp.where((counts > 0)[:, None], means, worst[None, :])
        return cents, None

    centroids, _ = jax.lax.scan(step, centroids, None, length=iters)
    return centroids


def quantization_error(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """Mean squared L2 distortion of the codebook on x."""
    ids = assign(x, centroids)
    return jnp.mean(jnp.sum((x - centroids[ids]) ** 2, axis=-1))
