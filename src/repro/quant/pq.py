"""Product quantization (Jégou et al.) — FaTRQ's coarse quantizer.

A D-dim vector is split into M subspaces of D/M dims, each quantized with
its own K-entry codebook (K=256 → 1 byte/subspace).  Asymmetric distance
computation (ADC) builds a per-query (M, K) lookup table of partial squared
distances; scoring a code is M table lookups + adds.

These are the "fast memory" structures of Fig. 3: codes (N, M) uint8 and
codebooks (M, K, D/M) stay hot; FaTRQ streams only residual codes from far
memory.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.quant.kmeans import assign, kmeans


@functools.partial(jax.tree_util.register_dataclass, data_fields=("codebooks",),
                   meta_fields=())
@dataclass(frozen=True)
class PQCodebook:
    codebooks: jax.Array   # (M, K, Ds)

    @property
    def m(self) -> int:
        return self.codebooks.shape[0]

    @property
    def k(self) -> int:
        return self.codebooks.shape[1]

    @property
    def ds(self) -> int:
        return self.codebooks.shape[2]

    @property
    def dim(self) -> int:
        return self.m * self.ds


def train(key: jax.Array, x: jax.Array, m: int, k: int = 256,
          iters: int = 20) -> PQCodebook:
    """Train M independent sub-codebooks on x (N, D)."""
    n, d = x.shape
    assert d % m == 0, f"D={d} not divisible by M={m}"
    subs = x.reshape(n, m, d // m).transpose(1, 0, 2)       # (M, N, Ds)
    keys = jax.random.split(key, m)
    books = jax.vmap(lambda kk, xs: kmeans(kk, xs, k, iters))(keys, subs)
    return PQCodebook(codebooks=books)


@jax.jit
def encode(cb: PQCodebook, x: jax.Array) -> jax.Array:
    """x (N, D) → codes (N, M) uint8 (K ≤ 256)."""
    n, d = x.shape
    subs = x.reshape(n, cb.m, cb.ds).transpose(1, 0, 2)
    ids = jax.vmap(assign)(subs, cb.codebooks)               # (M, N)
    return ids.T.astype(jnp.uint8)


def decode(cb: PQCodebook, codes: jax.Array) -> jax.Array:
    """codes (N, M) → reconstruction x_c (N, D)."""
    gathered = jax.vmap(lambda book, ids: book[ids], in_axes=(0, 1))(
        cb.codebooks, codes.astype(jnp.int32))               # (M, N, Ds)
    n = codes.shape[0]
    return gathered.transpose(1, 0, 2).reshape(n, cb.m * cb.ds)


def adc_table(cb: PQCodebook, q: jax.Array) -> jax.Array:
    """Per-query LUT (M, K): partial ||q_m − c_mk||²."""
    qs = q.reshape(cb.m, 1, cb.ds)
    diff = qs - cb.codebooks                                  # (M, K, Ds)
    return jnp.sum(diff * diff, axis=-1)


def adc_distances(table: jax.Array, codes: jax.Array) -> jax.Array:
    """Score codes (N, M) against a query LUT (M, K) → d̂₀ (N,)."""
    idx = codes.astype(jnp.int32)                             # (N, M)
    part = jax.vmap(lambda t, i: t[i], in_axes=(0, 1), out_axes=1)(table, idx)
    return jnp.sum(part, axis=-1)


def reconstruction_error(cb: PQCodebook, x: jax.Array) -> jax.Array:
    return jnp.mean(jnp.sum((x - decode(cb, encode(cb, x))) ** 2, axis=-1))
