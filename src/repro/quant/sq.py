"""Scalar-quantization baselines (§V-C comparisons).

* int8 full-vector SQ (the "w/o RQ" baseline in Fig. 7)
* b-bit residual SQ (the BANG-style residual scheme [12]): per-record
  min/max range, uniform levels — used at 3 and 4 bits in the paper.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SQCode(NamedTuple):
    codes: jax.Array   # (N, D) uint8
    lo: jax.Array      # (N,) per-record min
    step: jax.Array    # (N,) per-record step


def sq_encode(x: jax.Array, bits: int) -> SQCode:
    """Uniform per-record scalar quantization to 2^bits levels."""
    levels = (1 << bits) - 1
    lo = jnp.min(x, axis=-1)
    hi = jnp.max(x, axis=-1)
    step = jnp.maximum(hi - lo, 1e-12) / levels
    q = jnp.clip(jnp.round((x - lo[..., None]) / step[..., None]), 0, levels)
    return SQCode(codes=q.astype(jnp.uint8), lo=lo.astype(jnp.float32),
                  step=step.astype(jnp.float32))


def sq_decode(code: SQCode) -> jax.Array:
    return code.codes.astype(jnp.float32) * code.step[..., None] \
        + code.lo[..., None]


def sq_bytes_per_record(d: int, bits: int, *, n_scalars: int = 2) -> int:
    """Storage: ceil(D·bits/8) + range scalars."""
    return -(-d * bits // 8) + 4 * n_scalars


def int8_encode(x: jax.Array) -> SQCode:
    """Whole-vector int8 (the paper's "INT8 w/o RQ" line)."""
    return sq_encode(x, 8)
