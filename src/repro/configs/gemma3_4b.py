"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-*; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
    vocab=262144, head_dim=256, rope_theta=1e6, tie_embeddings=True,
    sliding_window=1024, local_global_ratio=5,
)
