"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (backbone only; patch
embeddings stubbed via input_specs).  [arXiv:2409.12191; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab=151936, qkv_bias=True, rope_style="mrope", rope_theta=1e6,
    tie_embeddings=True, frontend_stub=True,
)
