"""Architecture config schema for the 10 assigned architectures.

Every field is plain data (hashable, jit-static-friendly).  ``reduced()``
returns the smoke-test configuration of the same family (small layers/width,
few experts, tiny vocab) per the assignment spec.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None    # default d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    rope_style: str = "rope"       # rope | mrope | none
    # sliding-window / local-global attention (gemma3, mixtral)
    sliding_window: int = 0        # 0 → full attention
    local_global_ratio: int = 0    # gemma3: 5 local per 1 global
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    attn_every: int = 0            # zamba2: shared attn block period
    slstm_every: int = 0           # xlstm: sLSTM block period (else mLSTM)
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500
    # modality frontend stub (vlm / audio): inputs may be embeddings
    frontend_stub: bool = False
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (no full-attention layer over the full seq)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True   # all assigned archs have an autoregressive decoder

    def reduced(self) -> "ArchConfig":
        """Smoke-test config: same family/topology, tiny dims."""
        return replace(
            self,
            n_layers=5 if self.attn_every else 4,   # zamba: 2 groups + tail
            slstm_every=2 if self.slstm_every else 0,
            attn_every=2 if self.attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            < self.n_heads else 4,
            d_ff=256 if self.d_ff else 0,
            head_dim=32,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window
            else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            enc_frames=32 if self.enc_dec else self.enc_frames,
        )

    def params_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, f, hd = self.d_model, self.d_ff, self.hd
        h, kv = self.n_heads, self.n_kv_heads
        attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        if self.is_moe:
            ffn = self.n_experts * 3 * d * f
        elif f:
            ffn = 3 * d * f
        else:
            ffn = 0
        if self.ssm_state:
            d_inner = 2 * d
            ssm = d * (2 * d_inner + 2 * self.ssm_state) + d_inner * d
            if self.family == "ssm":
                # xlstm: blocks have their own up/down projections
                ssm = 6 * d * d
            core = ssm
            n_attn = (self.n_layers // self.attn_every) if self.attn_every \
                else 0
            total_core = self.n_layers * core + (attn + 3 * d * (2 * d)) * (
                1 if self.attn_every else 0)
        else:
            total_core = self.n_layers * (attn + ffn)
            n_attn = 0
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        enc = self.n_enc_layers * (attn + ffn) if self.enc_dec else 0
        # decoder cross-attn
        if self.enc_dec:
            total_core += self.n_layers * attn
        return total_core + emb + enc

    def active_params_count(self) -> int:
        """N_active for MoE (top-k experts instead of all)."""
        if not self.is_moe:
            return self.params_count()
        d, f = self.d_model, self.d_ff
        full = self.params_count()
        return full - self.n_layers * (self.n_experts - self.moe_top_k) \
            * 3 * d * f


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable?, reason-if-not) per assignment rules."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (f"{cfg.name} has full-attention layers — quadratic at "
                       "524288; skipped per spec (sub-quadratic archs only)")
    return True, ""
