"""Registry of the 10 assigned architectures (+ FaTRQ dataset configs)."""

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, \
    shape_applicable
from repro.configs.gemma3_4b import CONFIG as gemma3_4b
from repro.configs.mixtral_8x22b import CONFIG as mixtral_8x22b
from repro.configs.phi3_5_moe import CONFIG as phi3_5_moe
from repro.configs.qwen1_5_4b import CONFIG as qwen1_5_4b
from repro.configs.qwen2_5_3b import CONFIG as qwen2_5_3b
from repro.configs.qwen2_72b import CONFIG as qwen2_72b
from repro.configs.qwen2_vl_2b import CONFIG as qwen2_vl_2b
from repro.configs.whisper_medium import CONFIG as whisper_medium
from repro.configs.xlstm_1_3b import CONFIG as xlstm_1_3b
from repro.configs.zamba2_1_2b import CONFIG as zamba2_1_2b

ARCHS: dict[str, ArchConfig] = {c.name: c for c in [
    qwen2_vl_2b, qwen2_72b, qwen2_5_3b, qwen1_5_4b, gemma3_4b,
    mixtral_8x22b, phi3_5_moe, zamba2_1_2b, whisper_medium, xlstm_1_3b,
]}

__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeConfig",
           "shape_applicable"]
