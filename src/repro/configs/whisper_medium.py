"""whisper-medium [audio] — enc-dec, conv frontend stubbed (input_specs
provides precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=51865, rope_style="none", enc_dec=True, n_enc_layers=24,
    enc_frames=1500, frontend_stub=True,
)
