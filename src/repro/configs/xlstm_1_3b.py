"""xlstm-1.3b [ssm] — mLSTM blocks with periodic sLSTM (xLSTM[7:1]).
d_ff=0: blocks carry their own up/down projections.  [arXiv:2405.04517]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, rope_style="none", slstm_every=8, ssm_state=1,
)
