"""Process-local metrics registry: counters, gauges, histograms with
label sets.

Prometheus-shaped but dependency-free: a ``MetricsRegistry`` owns named
metrics; each metric fans out into labeled children (``labels(**kv)``)
that hold the actual values.  ``export.prometheus_text`` renders a
registry in the text exposition format; ``flat()`` returns one flat
``{"name{label=\"v\"}": value}`` dict for tests and quick printing.

Two registries matter in practice:

* the **default registry** (``active()`` with nothing else activated) —
  streaming-index mutation counters and ad-hoc instrumentation land
  here;
* a **per-engine registry** — ``ServingEngine`` owns one and activates
  it (``use``) for the duration of ``run()``, so datapath metrics
  recorded deep in the executor (e.g. ``fatrq_model_drift_ratio``)
  aggregate with the engine's own queue-wait / occupancy / cache series
  and export as one coherent scrape.

``add_collector(fn)`` registers a callback run at export time
(``collect()``) — used to mirror snapshot-style stats objects
(``ServingStats``, ``CacheStats``) into gauges without touching their
hot paths.
"""

from __future__ import annotations

import contextlib
import math
from contextvars import ContextVar

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "active", "use", "default_registry"]

DEFAULT_BUCKETS = (1.0, 10.0, 100.0, 1_000.0, 10_000.0, 100_000.0,
                   1_000_000.0)


def _label_key(labelnames: tuple, kv: dict) -> tuple:
    if set(kv) != set(labelnames):
        raise ValueError(f"labels {sorted(kv)} != declared "
                         f"{sorted(labelnames)}")
    return tuple(str(kv[n]) for n in labelnames)


def label_str(labelnames: tuple, values: tuple) -> str:
    """``{a="x",b="y"}`` suffix (empty string for unlabeled)."""
    if not labelnames:
        return ""
    inner = ",".join(f'{n}="{v}"' for n, v in zip(labelnames, values))
    return "{" + inner + "}"


class _Metric:
    """Base: named metric fanning out into per-label-set children."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: dict[tuple, object] = {}

    def labels(self, **kv):
        key = _label_key(self.labelnames, kv)
        child = self._children.get(key)
        if child is None:
            child = self._fresh_child()
            self._children[key] = child
        return child

    def _default_child(self):
        """The unlabeled child (only valid when labelnames is empty)."""
        if self.labelnames:
            raise ValueError(f"metric {self.name} requires labels "
                             f"{self.labelnames}")
        return self.labels()

    def children(self):
        """Deterministic iteration: (label-values tuple, child)."""
        return sorted(self._children.items())


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        self.value += v


class Counter(_Metric):
    kind = "counter"

    def _fresh_child(self):
        return _CounterChild()

    def inc(self, v: float = 1.0) -> None:
        self._default_child().inc(v)


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge(_Metric):
    kind = "gauge"

    def _fresh_child(self):
        return _GaugeChild()

    def set(self, v: float) -> None:
        self._default_child().set(v)


class _HistogramChild:
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: tuple):
        self.buckets = buckets
        # per-bucket (non-cumulative) counts; exporters cumulate
        self.counts = [0] * len(buckets)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.sum += v
        self.count += 1
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.counts[i] += 1
                break
        # v beyond the last bucket lands only in +Inf (the implicit
        # overflow bucket derived from ``count`` at export time)


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str, labelnames: tuple,
                 buckets: tuple = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs or any(math.isinf(b) for b in bs):
            raise ValueError("buckets must be finite and non-empty "
                             "(+Inf is implicit)")
        self.buckets = bs

    def _fresh_child(self):
        return _HistogramChild(self.buckets)

    def observe(self, v: float) -> None:
        self._default_child().observe(v)


class MetricsRegistry:
    """Named metrics + export-time collectors.  Getter methods are
    idempotent: re-declaring a metric with the same kind/labels returns
    the existing one; a conflicting redeclaration raises."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}
        self._collectors: list = []

    def _get(self, cls, name: str, help: str, labelnames: tuple, **kw):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls) or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind} with "
                    f"labels {m.labelnames}")
            return m
        m = cls(name, help, tuple(labelnames), **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labelnames: tuple = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "", labelnames: tuple = (),
                  buckets: tuple = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    def add_collector(self, fn) -> None:
        """Register ``fn()`` to run before every export/flatten — mirror
        snapshot stats into gauges here, not on the hot path."""
        self._collectors.append(fn)

    def collect(self) -> None:
        for fn in self._collectors:
            fn()

    def metrics(self) -> list[_Metric]:
        """Deterministic (name-sorted) metric list; runs collectors."""
        self.collect()
        return [self._metrics[n] for n in sorted(self._metrics)]

    def flat(self) -> dict[str, float]:
        """One flat ``{"name{labels}": value}`` dict.  Histograms expose
        ``name_count`` / ``name_sum`` (buckets stay in the Prometheus
        exposition)."""
        out: dict[str, float] = {}
        for m in self.metrics():
            for values, child in m.children():
                suffix = label_str(m.labelnames, values)
                if m.kind == "histogram":
                    out[f"{m.name}_count{suffix}"] = child.count
                    out[f"{m.name}_sum{suffix}"] = child.sum
                else:
                    out[f"{m.name}{suffix}"] = child.value
        return out


_DEFAULT = MetricsRegistry()
_ACTIVE: ContextVar[MetricsRegistry | None] = ContextVar(
    "fatrq_active_registry", default=None)


def default_registry() -> MetricsRegistry:
    return _DEFAULT


def active() -> MetricsRegistry:
    """The registry activated by ``use`` (the process default when none
    is active — metrics are always recordable, unlike spans)."""
    reg = _ACTIVE.get()
    return _DEFAULT if reg is None else reg


@contextlib.contextmanager
def use(registry: MetricsRegistry):
    """Route ``active()`` to ``registry`` for the block's extent (the
    serving engine wraps ``run()`` in this so executor-level metrics land
    in the engine's registry)."""
    token = _ACTIVE.set(registry)
    try:
        yield registry
    finally:
        _ACTIVE.reset(token)
