"""Exporters: JSONL span dumps, Chrome-trace JSON, Prometheus text.

* ``write_jsonl`` — one JSON object per span, creation order, sorted
  keys.  With ``include_wall=False`` every wall-clock timestamp and
  every attribute whose key starts with ``"wall"`` is stripped, so a
  seeded virtual-clock trace exports BYTE-IDENTICALLY across runs
  (pinned in ``tests/test_obs.py``).
* ``chrome_trace`` — the Chrome trace-event format (loadable in
  ``chrome://tracing`` / Perfetto), rendered from VIRTUAL-clock
  timestamps only: each span track becomes a named thread, spans with a
  virtual interval become complete (``"X"``) events, zero-duration /
  point spans become instant (``"i"``) events.  This is how the serving
  engine's overlapped front/refine pipeline is visualized.
* ``prometheus_text`` — the text exposition format (``# HELP`` /
  ``# TYPE`` + samples; histograms emit cumulative ``_bucket{le=...}``
  series plus ``_sum`` / ``_count``).
"""

from __future__ import annotations

import json

from repro.obs.metrics import MetricsRegistry, label_str
from repro.obs.trace import Span

__all__ = ["span_records", "write_jsonl", "chrome_trace",
           "write_chrome_trace", "prometheus_text", "write_prometheus"]


# ------------------------------------------------------------------- JSONL


def span_records(spans: list[Span], *, include_wall: bool = True
                 ) -> list[dict]:
    return [s.to_record(include_wall=include_wall) for s in spans]


def write_jsonl(spans: list[Span], path: str, *,
                include_wall: bool = True) -> str:
    with open(path, "w") as f:
        for rec in span_records(spans, include_wall=include_wall):
            f.write(json.dumps(rec, sort_keys=True))
            f.write("\n")
    return path


# ------------------------------------------------------------ Chrome trace


def chrome_trace(spans: list[Span], *, process_name: str = "fatrq") -> dict:
    """Spans with virtual timestamps → Chrome trace-event JSON dict.

    Tracks map to thread ids in sorted-name order (deterministic);
    spans without any virtual timestamp are skipped (they never ran
    under a virtual clock, so there is no consistent timeline to place
    them on).
    """
    tracks = sorted({s.track for s in spans
                     if s.virtual_start_us is not None})
    tid_of = {t: i + 1 for i, t in enumerate(tracks)}
    events: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": process_name}},
    ]
    for t in tracks:
        events.append({"name": "thread_name", "ph": "M", "pid": 1,
                       "tid": tid_of[t], "args": {"name": t}})
    for s in spans:
        if s.virtual_start_us is None:
            continue
        args = {k: v for k, v in s.attrs.items()
                if not k.startswith("wall")}
        args["sid"] = s.sid
        base = {"name": s.name, "pid": 1, "tid": tid_of[s.track],
                "cat": s.track, "args": args}
        if s.virtual_end_us is not None \
                and s.virtual_end_us > s.virtual_start_us:
            events.append({**base, "ph": "X", "ts": s.virtual_start_us,
                           "dur": s.virtual_end_us - s.virtual_start_us})
        else:
            events.append({**base, "ph": "i", "ts": s.virtual_start_us,
                           "s": "t"})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(spans: list[Span], path: str, **kw) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(spans, **kw), f, sort_keys=True)
    return path


# -------------------------------------------------------------- Prometheus


def prometheus_text(registry: MetricsRegistry) -> str:
    """Render ``registry`` in the Prometheus text exposition format."""
    lines: list[str] = []
    for m in registry.metrics():
        if m.help:
            lines.append(f"# HELP {m.name} {m.help}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        for values, child in m.children():
            suffix = label_str(m.labelnames, values)
            if m.kind == "histogram":
                cum = 0
                for ub, c in zip(child.buckets, child.counts):
                    cum += c
                    le = label_str(m.labelnames + ("le",),
                                   values + (_fmt(ub),))
                    lines.append(f"{m.name}_bucket{le} {cum}")
                le = label_str(m.labelnames + ("le",), values + ("+Inf",))
                lines.append(f"{m.name}_bucket{le} {child.count}")
                lines.append(f"{m.name}_sum{suffix} {_fmt(child.sum)}")
                lines.append(f"{m.name}_count{suffix} {child.count}")
            else:
                lines.append(f"{m.name}{suffix} {_fmt(child.value)}")
    return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    """Compact sample formatting: integers render bare."""
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def write_prometheus(registry: MetricsRegistry, path: str) -> str:
    with open(path, "w") as f:
        f.write(prometheus_text(registry))
    return path
