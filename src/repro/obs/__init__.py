"""Observability subsystem: query-lifecycle tracing, process-local
metrics, and exporters (JSONL spans, Chrome-trace JSON, Prometheus text).

The paper's whole argument is a latency budget — far-memory residual
reads and early-exit pruning dominate query time — so this package makes
the real per-stage breakdown visible next to the modeled one:

* ``trace``   — hierarchical spans with a context-var trace context,
  wall-clock + virtual-clock dual timestamps, per-span attributes.
  Disabled by default: every instrumentation site goes through
  ``trace.span(...)``, which is a single context-var read returning a
  shared no-op handle when no tracer is active (zero-cost fast path —
  no jit-visible work either way, pinned in ``tests/test_obs.py``).
* ``metrics`` — process-local registry of counters / gauges /
  histograms with label sets; the serving engine keeps one per engine,
  everything else uses the active (default) registry.
* ``export``  — JSONL span dump (byte-deterministic under the virtual
  clock), Chrome-trace/Perfetto JSON rendered from virtual-clock spans,
  and Prometheus text exposition of a registry.

The key derived signal is ``fatrq_model_drift_ratio{stage=...}``: every
traced stage records both its measured wall time and its
``QueryCost``-modeled time, so the histogram quantifies where the
Table-I tier model diverges from reality — the feedback signal adaptive
hot/cold placement needs.
"""

from repro.obs import export, metrics, trace
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import NOOP_SPAN, Span, Tracer

__all__ = ["export", "metrics", "trace",
           "Counter", "Gauge", "Histogram", "MetricsRegistry",
           "NOOP_SPAN", "Span", "Tracer"]
