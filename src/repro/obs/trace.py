"""Hierarchical query-lifecycle spans with a context-var trace context.

A ``Tracer`` collects ``Span`` records: name, monotonic span id, parent
id (nesting follows the context-var current-span stack), a ``track``
(the logical execution unit the span ran on — ``"query"`` for datapath
stages, ``"unit:front"`` / ``"unit:refine"`` for the serving engine's
virtual pipeline units, ``"sched"`` for scheduler events, ``"index"``
for streaming mutations), free-form JSON-serializable attributes, and
DUAL timestamps:

* **wall clock** — ``time.perf_counter()`` seconds around the host-side
  stage call.  Instrumented stages block on their device results before
  closing the span (the executor adds the sync only when tracing is
  active), so the wall time covers the device work, not just the async
  enqueue.
* **virtual clock** — microseconds from an attached clock source
  (``Tracer.virtual_clock``, wired to the serving engine's deterministic
  ``VirtualClock``).  Virtual timestamps are what make traces replayable
  and byte-identical in tests; spans created outside a virtual-clocked
  context carry ``None``.

Zero-cost when disabled: the module-level ``span()`` / ``event()``
helpers read one context var and return the shared ``NOOP_SPAN`` when no
tracer is active — no allocation, no clock reads, and (because all
instrumentation is host-side) no change to any jit trace or cache
(pinned by the no-recompile test in ``tests/test_obs.py``).

Determinism: span ids are assigned in creation order, so the same
seeded serving trace produces the identical span tree; exporting with
wall times stripped (``export.write_jsonl(..., include_wall=False)``)
yields byte-identical files across runs.
"""

from __future__ import annotations

import contextlib
import time
from contextvars import ContextVar
from dataclasses import dataclass, field

__all__ = ["Span", "Tracer", "NOOP_SPAN", "active", "span", "event", "use"]

_ACTIVE: ContextVar["Tracer | None"] = ContextVar("fatrq_active_tracer",
                                                  default=None)


@dataclass
class Span:
    """One traced operation.  ``None`` timestamps mean the clock did not
    apply (no virtual clock attached / explicit-time span without wall
    times).  ``attrs`` keys starting with ``"wall"`` are treated as
    wall-derived by the exporters and stripped from deterministic
    exports alongside the wall timestamps."""

    sid: int
    parent: int | None
    name: str
    track: str = "main"
    wall_start_s: float | None = None
    wall_end_s: float | None = None
    virtual_start_us: float | None = None
    virtual_end_us: float | None = None
    attrs: dict = field(default_factory=dict)

    @property
    def wall_s(self) -> float | None:
        if self.wall_start_s is None or self.wall_end_s is None:
            return None
        return self.wall_end_s - self.wall_start_s

    @property
    def virtual_us(self) -> float | None:
        if self.virtual_start_us is None or self.virtual_end_us is None:
            return None
        return self.virtual_end_us - self.virtual_start_us

    def to_record(self, *, include_wall: bool = True) -> dict:
        rec = {"sid": self.sid, "parent": self.parent, "name": self.name,
               "track": self.track,
               "virtual_start_us": self.virtual_start_us,
               "virtual_end_us": self.virtual_end_us}
        if include_wall:
            rec["wall_start_s"] = self.wall_start_s
            rec["wall_end_s"] = self.wall_end_s
            rec["attrs"] = dict(self.attrs)
        else:
            rec["attrs"] = {k: v for k, v in self.attrs.items()
                            if not k.startswith("wall")}
        return rec


class _SpanHandle:
    """Context manager returned by ``Tracer.span``: enters by pushing the
    span onto the current-span context var, exits by stamping end times
    and popping.  ``set_attr`` works before and after exit (stage
    instrumentation attaches modeled times post-fold)."""

    __slots__ = ("_tracer", "span", "_token")

    def __init__(self, tracer: "Tracer", sp: Span):
        self._tracer = tracer
        self.span = sp
        self._token = None

    def set_attr(self, key: str, value) -> None:
        self.span.attrs[key] = value

    def set_attrs(self, **kv) -> None:
        self.span.attrs.update(kv)

    def __enter__(self) -> "_SpanHandle":
        self._token = self._tracer._current.set(self.span.sid)
        return self

    def __exit__(self, *exc) -> bool:
        sp = self.span
        sp.wall_end_s = time.perf_counter()
        clock = self._tracer.virtual_clock
        if clock is not None:
            sp.virtual_end_us = float(clock())
        self._tracer._current.reset(self._token)
        return False


class _NoopSpan:
    """Shared do-nothing handle for the disabled fast path."""

    __slots__ = ()
    span = None

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set_attr(self, key: str, value) -> None:
        pass

    def set_attrs(self, **kv) -> None:
        pass


NOOP_SPAN = _NoopSpan()


class Tracer:
    """Span collector.  ``virtual_clock`` is an optional zero-arg callable
    returning the current virtual time in microseconds (the serving
    engine wires its ``VirtualClock`` in); spans stamp it on entry/exit
    alongside the wall clock."""

    def __init__(self, virtual_clock=None):
        self.spans: list[Span] = []
        self.virtual_clock = virtual_clock
        self._next_sid = 0
        self._current: ContextVar[int | None] = ContextVar(
            "fatrq_current_span", default=None)

    # -- creation ---------------------------------------------------------

    def _fresh(self, name: str, track: str, parent: int | None,
               attrs: dict) -> Span:
        sp = Span(sid=self._next_sid, parent=parent, name=name, track=track,
                  attrs=attrs)
        self._next_sid += 1
        self.spans.append(sp)
        return sp

    def span(self, name: str, *, track: str = "main", **attrs) -> _SpanHandle:
        """Open a timed span nested under the current one (context
        manager).  Wall start stamps immediately; virtual start stamps
        when a virtual clock is attached."""
        sp = self._fresh(name, track, self._current.get(), attrs)
        sp.wall_start_s = time.perf_counter()
        if self.virtual_clock is not None:
            sp.virtual_start_us = float(self.virtual_clock())
        return _SpanHandle(self, sp)

    def event(self, name: str, *, track: str = "main",
              parent: int | None = None, virtual_us: float | None = None,
              **attrs) -> Span:
        """Zero-duration annotation span (throttle fired, cache hit,
        compile-cache probe, per-level refine stats).  ``parent`` defaults
        to the current span; ``virtual_us`` overrides the attached
        clock's reading (the scheduler back-stamps event times)."""
        parent = parent if parent is not None else self._current.get()
        sp = self._fresh(name, track, parent, attrs)
        sp.wall_start_s = sp.wall_end_s = time.perf_counter()
        if virtual_us is None and self.virtual_clock is not None:
            virtual_us = float(self.virtual_clock())
        if virtual_us is not None:
            sp.virtual_start_us = sp.virtual_end_us = float(virtual_us)
        return sp

    def add_span(self, name: str, *, track: str = "main",
                 virtual_start_us: float, virtual_end_us: float,
                 parent: int | None = None,
                 wall_start_s: float | None = None,
                 wall_end_s: float | None = None, **attrs) -> Span:
        """Explicit-interval span: the serving engine's virtual pipeline
        units compute their occupancy retroactively (a batch's front/
        refine interval is known only at completion), so their spans are
        recorded with explicit virtual times rather than enter/exit."""
        parent = parent if parent is not None else self._current.get()
        sp = self._fresh(name, track, parent, attrs)
        sp.virtual_start_us = float(virtual_start_us)
        sp.virtual_end_us = float(virtual_end_us)
        sp.wall_start_s = wall_start_s
        sp.wall_end_s = wall_end_s
        return sp

    # -- inspection -------------------------------------------------------

    def by_name(self, name: str) -> list[Span]:
        return [s for s in self.spans if s.name == name]

    def children(self, sid: int) -> list[Span]:
        return [s for s in self.spans if s.parent == sid]


# ---------------------------------------------------------- module helpers
# Instrumentation sites call these, not Tracer methods: one context-var
# read when disabled, nothing else.


def active() -> Tracer | None:
    """The tracer activated by ``use`` (None = tracing disabled)."""
    return _ACTIVE.get()


def span(name: str, *, track: str = "main", **attrs):
    """Open a span on the active tracer; the shared no-op handle when
    tracing is disabled (the zero-cost fast path)."""
    tr = _ACTIVE.get()
    if tr is None:
        return NOOP_SPAN
    return tr.span(name, track=track, **attrs)


def event(name: str, *, track: str = "main", **attrs) -> Span | None:
    """Record an event on the active tracer; no-op when disabled."""
    tr = _ACTIVE.get()
    if tr is None:
        return None
    return tr.event(name, track=track, **attrs)


@contextlib.contextmanager
def use(tracer: Tracer):
    """Activate ``tracer`` for the dynamic extent of the block."""
    token = _ACTIVE.set(tracer)
    try:
        yield tracer
    finally:
        _ACTIVE.reset(token)
