"""IVF index (inverted-file) — the paper's primary front stage.

Build: k-means coarse centroids (nlist), assign every record to its nearest
centroid, materialize fixed-capacity inverted lists (padded with -1 so the
whole search is jit-able / shard_map-able; padding follows the FAISS
convention of bounded list length).

Search: rank lists by centroid distance, take nprobe, gather member ids →
the candidate set handed to PQ-ADC scoring.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.kmeans import assign, kmeans


@partial(jax.tree_util.register_dataclass,
         data_fields=("centroids", "lists", "list_len"), meta_fields=())
@dataclass(frozen=True)
class IVFIndex:
    centroids: jax.Array   # (nlist, D)
    lists: jax.Array       # (nlist, cap) int32, -1 padded
    list_len: jax.Array    # (nlist,) int32

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def cap(self) -> int:
        return self.lists.shape[1]


def fill_lists(ids: np.ndarray, nlist: int, cap: int
               ) -> tuple[np.ndarray, np.ndarray, int]:
    """Vectorized inverted-list fill: bucketize ``ids`` (N,) into a
    (nlist, cap') id matrix (-1 padded) + per-list lengths.

    No record is ever dropped: when the largest bucket exceeds ``cap`` the
    capacity SPILLS to fit it (returned ``n_spilled`` counts the rows past
    the requested cap, for skew monitoring).  Member order within each list
    matches the original append order (ascending record id) via a stable
    argsort, so the fill is a drop-in for the old O(N)-Python loop — minus
    its silent overflow drop.  Shared by the offline ``build`` and the
    streaming subsystem's ``compact()`` (anns/streaming.py).
    """
    n = ids.shape[0]
    counts = np.bincount(ids, minlength=nlist).astype(np.int32)
    n_spilled = int(np.maximum(counts - cap, 0).sum())
    cap = max(cap, int(counts.max()) if n else 1, 1)
    order = np.argsort(ids, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(n) - starts[ids[order]]
    lists = np.full((nlist, cap), -1, np.int32)
    lists[ids[order], pos] = order
    return lists, counts, n_spilled


def build(key: jax.Array, x: jax.Array, nlist: int, *, iters: int = 20,
          cap_factor: float = 3.0) -> IVFIndex:
    """Train centroids and fill inverted lists (host-side fill, device arrays
    out).  cap = cap_factor × N/nlist bounds skew; a hotter list spills the
    capacity rather than silently dropping members (the pre-vectorization
    fill loop lost any record past cap)."""
    n = x.shape[0]
    centroids = kmeans(key, x, nlist, iters)
    ids = np.asarray(assign(x, centroids))
    cap = int(cap_factor * n / nlist) + 1
    lists, lens, _ = fill_lists(ids, nlist, cap)
    return IVFIndex(centroids=jnp.asarray(centroids),
                    lists=jnp.asarray(lists), list_len=jnp.asarray(lens))


@partial(jax.jit, static_argnames=("nprobe",))
def probe(index: IVFIndex, q: jax.Array, *, nprobe: int) -> jax.Array:
    """Candidate ids for query q (D,) → (nprobe·cap,) int32 with -1 pads."""
    d = jnp.sum((index.centroids - q[None]) ** 2, axis=-1)
    _, top_lists = jax.lax.top_k(-d, nprobe)
    return index.lists[top_lists].reshape(-1)


def probe_batch(index: IVFIndex, qs: jax.Array, *, nprobe: int) -> jax.Array:
    return jax.vmap(lambda q: probe(index, q, nprobe=nprobe))(qs)


def assign_lists(index: IVFIndex, x: jax.Array) -> jax.Array:
    """Which inverted list each vector belongs to (nearest centroid)."""
    return assign(x, index.centroids)
