"""CAGRA-style fixed-degree graph index.

Build: exact kNN graph (blocked brute force — fine at reproduction scale;
CAGRA's NN-descent converges to the same neighborhood structure) with a
rank-based pruning pass for diversity.  Search: batched greedy best-first
beam search with a fixed iteration budget — jit-able (no data-dependent
control flow: every iteration expands the best unvisited beam entry).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.data.synthetic import brute_force_topk


@partial(jax.tree_util.register_dataclass, data_fields=("neighbors",),
         meta_fields=())
@dataclass(frozen=True)
class GraphIndex:
    neighbors: jax.Array   # (N, degree) int32

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]


def build(x: jax.Array, degree: int = 16) -> GraphIndex:
    """kNN graph + reverse-edge augmentation (CAGRA's rank-based reordering
    keeps forward kNN edges; adding reverse edges restores reachability of
    hub-adjacent points, which plain kNN graphs lose)."""
    import numpy as np

    n = x.shape[0]
    fwd = int(degree * 3 / 4)
    knn = np.asarray(brute_force_topk(x, x, degree + 1))
    ids = np.arange(n)[:, None]
    mask = knn != ids
    order = np.argsort(~mask, axis=1, kind="stable")
    pruned = np.take_along_axis(knn, order, axis=1)[:, :degree]

    neighbors = np.full((n, degree), -1, np.int32)
    neighbors[:, :fwd] = pruned[:, :fwd]
    # reverse edges: j appears in i's reverse list if i ∈ knn(j)
    fill = np.full((n,), fwd, np.int32)
    for j in range(n):
        for i in pruned[j, :fwd]:
            if fill[i] < degree:
                neighbors[i, fill[i]] = j
                fill[i] += 1
    # pad any remaining -1 with forward edges
    for i in range(n):
        k = fill[i]
        if k < degree:
            neighbors[i, k:] = pruned[i, fwd:fwd + (degree - k)]
    # long-range shortcuts: kNN graphs over clustered data decompose into
    # per-cluster components; two random edges per node make the graph an
    # expander so beam search can escape a wrong-cluster basin (plays the
    # role of CAGRA's NN-descent mixing / HNSW's upper layers).
    rng = np.random.default_rng(7)
    shortcuts = rng.integers(0, n, size=(n, 2))
    neighbors[:, degree - 2:] = shortcuts
    return GraphIndex(neighbors=jnp.asarray(neighbors))


@partial(jax.jit, static_argnames=("iters", "beam", "expand"))
def search(index: GraphIndex, x: jax.Array, q: jax.Array, *, iters: int = 24,
           beam: int = 64, expand: int = 4, seed: int = 0) -> jax.Array:
    """Greedy beam search for one query; returns the beam (candidate ids).

    Expands the `expand` best unexpanded beam entries per iteration (CAGRA's
    parallel expansion).  Distances use full vectors here (build-time /
    oracle use); the ANNS pipeline scores with PQ-ADC instead.
    """
    n, d = x.shape
    key = jax.random.PRNGKey(seed)
    start = jax.random.randint(key, (beam,), 0, n)

    def dist(ids):
        return jnp.sum((x[ids] - q[None]) ** 2, axis=-1)

    beam_ids = start
    beam_d = dist(start)
    visited_mask = jnp.zeros((beam,), bool)  # which beam slots were expanded

    def body(carry, _):
        ids, ds, expanded = carry
        # pick `expand` best unexpanded beam entries
        cand_score = jnp.where(expanded, jnp.inf, ds)
        _, picks = jax.lax.top_k(-cand_score, expand)
        expanded = expanded.at[picks].set(True)
        neigh = index.neighbors[ids[picks]].reshape(-1)       # (E·degree,)
        neigh = jnp.maximum(neigh, 0)
        nd = dist(neigh)
        all_ids = jnp.concatenate([ids, neigh])
        all_d = jnp.concatenate([ds, nd])
        all_exp = jnp.concatenate([expanded,
                                   jnp.zeros_like(nd, bool)])
        # dedup: penalize repeated ids so they sort last (first occurrence —
        # the beam copy carrying its `expanded` flag — survives)
        sort_ids = jnp.argsort(all_ids, stable=True)
        sorted_ids = all_ids[sort_ids]
        dup = jnp.concatenate([jnp.array([False]),
                               sorted_ids[1:] == sorted_ids[:-1]])
        dup_in_orig = jnp.zeros_like(dup).at[sort_ids].set(dup)
        all_d = jnp.where(dup_in_orig, jnp.inf, all_d)
        _, keep = jax.lax.top_k(-all_d, beam)
        return (all_ids[keep], all_d[keep], all_exp[keep]), None

    (beam_ids, beam_d, _), _ = jax.lax.scan(
        body, (beam_ids, beam_d, visited_mask), None, length=iters)
    order = jnp.argsort(beam_d)
    return beam_ids[order]


def search_batch(index: GraphIndex, x: jax.Array, qs: jax.Array,
                 *, iters: int = 24, beam: int = 64) -> jax.Array:
    return jax.vmap(lambda q: search(index, x, q, iters=iters, beam=beam))(qs)
