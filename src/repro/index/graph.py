"""CAGRA-style fixed-degree graph index.

Build: exact kNN graph (blocked brute force — fine at reproduction scale;
CAGRA's NN-descent converges to the same neighborhood structure) with a
rank-based pruning pass for diversity.  Search: batched greedy best-first
beam search with a fixed iteration budget — jit-able (no data-dependent
control flow: every iteration expands the best unvisited beam entry).

The per-iteration beam step is split into two shared helpers —
``pick_frontier`` (select the best unexpanded slots) and ``beam_merge``
(dedup + keep the ``beam`` best) — so the sharded traversal in
``anns.sharding`` can interleave a cross-shard frontier exchange between
them while staying BIT-IDENTICAL to this single-device search: both paths
run the exact same dedup/tie-breaking ops on the exact same values.

Online maintenance (FreshDiskANN-style, used by ``anns.streaming``):
``insert_nodes`` wires freshly appended vectors into an existing graph
(beam-search neighborhood → forward edges, replace-worst reverse edges);
deletes are tombstones at the search layer (traversal routes THROUGH dead
nodes); ``compact_graph`` drops dead rows at compaction time and patches
edges through them with a one-hop contraction.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.data.synthetic import brute_force_topk


@partial(jax.tree_util.register_dataclass, data_fields=("neighbors",),
         meta_fields=())
@dataclass(frozen=True)
class GraphIndex:
    neighbors: jax.Array   # (N, degree) int32

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]


def build(x: jax.Array, degree: int = 16) -> GraphIndex:
    """kNN graph + reverse-edge augmentation (CAGRA's rank-based reordering
    keeps forward kNN edges; adding reverse edges restores reachability of
    hub-adjacent points, which plain kNN graphs lose)."""
    import numpy as np

    n = x.shape[0]
    fwd = int(degree * 3 / 4)
    knn = np.asarray(brute_force_topk(x, x, degree + 1))
    ids = np.arange(n)[:, None]
    mask = knn != ids
    order = np.argsort(~mask, axis=1, kind="stable")
    pruned = np.take_along_axis(knn, order, axis=1)[:, :degree]

    neighbors = np.full((n, degree), -1, np.int32)
    neighbors[:, :fwd] = pruned[:, :fwd]
    # reverse edges: j appears in i's reverse list if i ∈ knn(j), taken in
    # (j, rank) order with at most degree-fwd accepted per target — a
    # stable argsort over the flattened edge list groups edges by target
    # while preserving exactly that order, so the scatter fills the same
    # slots the old per-edge Python loop did.
    targets = pruned[:, :fwd].reshape(-1)
    sources = np.repeat(np.arange(n), fwd).astype(np.int32)
    by_tgt = np.argsort(targets, kind="stable")
    t_sorted, s_sorted = targets[by_tgt], sources[by_tgt]
    first = np.r_[True, t_sorted[1:] != t_sorted[:-1]]
    grp_start = np.maximum.accumulate(
        np.where(first, np.arange(t_sorted.size), 0))
    rank = np.arange(t_sorted.size) - grp_start
    take = rank < degree - fwd
    neighbors[t_sorted[take], fwd + rank[take]] = s_sorted[take]
    fill = fwd + np.minimum(np.bincount(targets, minlength=n), degree - fwd)
    # pad any remaining -1 with forward edges
    cols = np.arange(degree)[None, :]
    src = np.clip(fwd + cols - fill[:, None], 0, degree - 1)
    pad = np.take_along_axis(pruned, src, axis=1)
    neighbors = np.where(cols >= fill[:, None], pad, neighbors)
    # long-range shortcuts: kNN graphs over clustered data decompose into
    # per-cluster components; two random edges per node make the graph an
    # expander so beam search can escape a wrong-cluster basin (plays the
    # role of CAGRA's NN-descent mixing / HNSW's upper layers).
    rng = np.random.default_rng(7)
    shortcuts = rng.integers(0, n, size=(n, 2))
    neighbors[:, degree - 2:] = shortcuts
    return GraphIndex(neighbors=jnp.asarray(neighbors.astype(np.int32)))


# ------------------------------------------------------- beam-step helpers


def pick_frontier(ds: jax.Array, expanded: jax.Array, *, expand: int
                  ) -> tuple[jax.Array, jax.Array]:
    """Select the ``expand`` best unexpanded beam slots.
    Returns (picked slot indices, updated expanded mask)."""
    cand_score = jnp.where(expanded, jnp.inf, ds)
    _, picks = jax.lax.top_k(-cand_score, expand)
    return picks, expanded.at[picks].set(True)


def beam_merge(ids: jax.Array, ds: jax.Array, expanded: jax.Array,
               new_ids: jax.Array, new_d: jax.Array, *, beam: int
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Merge expansion results into the beam: concat [beam, new], penalize
    duplicate ids so the first occurrence (the beam copy carrying its
    ``expanded`` flag) survives, keep the ``beam`` smallest.

    This is THE bit-level beam-update contract: the sharded frontier
    exchange calls it on the psum'd neighbor lists, so its dedup order and
    ``top_k`` tie-breaking match the single-device search exactly.
    """
    all_ids = jnp.concatenate([ids, new_ids])
    all_d = jnp.concatenate([ds, new_d])
    all_exp = jnp.concatenate([expanded, jnp.zeros(new_ids.shape, bool)])
    sort_ids = jnp.argsort(all_ids, stable=True)
    sorted_ids = all_ids[sort_ids]
    dup = jnp.concatenate([jnp.array([False]),
                           sorted_ids[1:] == sorted_ids[:-1]])
    dup_in_orig = jnp.zeros_like(dup).at[sort_ids].set(dup)
    all_d = jnp.where(dup_in_orig, jnp.inf, all_d)
    _, keep = jax.lax.top_k(-all_d, beam)
    return all_ids[keep], all_d[keep], all_exp[keep]


@partial(jax.jit, static_argnames=("iters", "beam", "expand"))
def search(index: GraphIndex, x: jax.Array, q: jax.Array, *, iters: int = 24,
           beam: int = 64, expand: int = 4, seed: int = 0) -> jax.Array:
    """Greedy beam search for one query; returns the beam (candidate ids).

    Expands the `expand` best unexpanded beam entries per iteration (CAGRA's
    parallel expansion).  Distances use full vectors here (build-time /
    oracle use); the ANNS pipeline scores with PQ-ADC instead.
    """
    n, d = x.shape
    key = jax.random.PRNGKey(seed)
    start = jax.random.randint(key, (beam,), 0, n)

    def dist(ids):
        return jnp.sum((x[ids] - q[None]) ** 2, axis=-1)

    beam_ids = start
    beam_d = dist(start)
    visited_mask = jnp.zeros((beam,), bool)  # which beam slots were expanded

    def body(carry, _):
        ids, ds, expanded = carry
        picks, expanded = pick_frontier(ds, expanded, expand=expand)
        neigh = index.neighbors[ids[picks]].reshape(-1)       # (E·degree,)
        neigh = jnp.maximum(neigh, 0)
        nd = dist(neigh)
        return beam_merge(ids, ds, expanded, neigh, nd, beam=beam), None

    (beam_ids, beam_d, _), _ = jax.lax.scan(
        body, (beam_ids, beam_d, visited_mask), None, length=iters)
    order = jnp.argsort(beam_d)
    return beam_ids[order]


def search_batch(index: GraphIndex, x: jax.Array, qs: jax.Array,
                 *, iters: int = 24, beam: int = 64) -> jax.Array:
    return jax.vmap(lambda q: search(index, x, q, iters=iters, beam=beam))(qs)


# ----------------------------------------------------- online maintenance


def insert_nodes(neighbors, x, n_old: int, *, iters: int = 32,
                 beam: int = 64, expand: int = 4):
    """Wire rows ``n_old:`` of ``x`` into an existing graph online
    (FreshDiskANN-style RobustInsert adapted to the fixed-degree layout).

    Each new node beam-searches the PRE-BATCH graph and takes its `degree`
    nearest beam entries as forward edges; reverse edges replace the
    target's current worst edge when the new node is closer, and the single
    NEAREST neighbor always accepts one reverse edge so every inserted node
    is reachable immediately (no rebuild, no edge ever dangles).  Returns
    the grown (n, degree) int32 adjacency.  Deterministic: new nodes are
    wired in row order with the same seed the static build's search uses.
    """
    import numpy as np

    nb = np.asarray(neighbors)
    x_np = np.asarray(x, np.float32)
    n, degree = x_np.shape[0], nb.shape[1]
    b = n - n_old
    if b <= 0:
        return nb.astype(np.int32)
    if nb.shape[0] != n_old:
        raise ValueError(f"adjacency covers {nb.shape[0]} rows but "
                         f"n_old={n_old}")
    gidx = GraphIndex(neighbors=jnp.asarray(nb))
    x_old = jnp.asarray(x_np[:n_old])
    beams = np.asarray(jax.vmap(
        lambda q: search(gidx, x_old, q, iters=iters, beam=beam,
                         expand=expand))(jnp.asarray(x_np[n_old:])))

    out = np.concatenate([nb, np.zeros((b, degree), np.int32)])
    for t in range(b):
        row = n_old + t
        fwd = beams[t, :degree].astype(np.int32)
        out[row] = fwd
        d_new = np.sum((x_np[fwd] - x_np[row]) ** 2, axis=-1)
        for j, tgt in enumerate(fwd.tolist()):
            if row in out[tgt]:
                continue
            cur_d = np.sum((x_np[out[tgt]] - x_np[tgt]) ** 2, axis=-1)
            worst = int(np.argmax(cur_d))
            if j == 0 or d_new[j] < cur_d[worst]:
                out[tgt, worst] = row
    return out.astype(np.int32)


def compact_graph(neighbors, x, live_rows):
    """Drop dead rows at compaction time and patch edges through them.

    ``live_rows`` (ascending old row ids) defines the old→new renumbering.
    Surviving edges are remapped directly; an edge into a dead node is
    replaced by a one-hop contraction — the dead node's own nearest live
    neighbor (ranked by distance to the edge's source), skipping rows the
    source already links to.  If contraction finds nothing (all of the dead
    node's neighborhood is dead or already linked), the source's first live
    edge is duplicated — a redundant edge is harmless to beam search, a -1
    would not be.  Returns the (n_live, degree) int32 adjacency.
    """
    import numpy as np

    nb = np.asarray(neighbors)
    x_np = np.asarray(x, np.float32)
    live_rows = np.asarray(live_rows)
    n_live = live_rows.size
    if n_live == 0:
        raise ValueError("cannot compact a graph to zero live rows")
    new_of = np.full(nb.shape[0], -1, np.int32)
    new_of[live_rows] = np.arange(n_live, dtype=np.int32)
    out = new_of[nb[live_rows]]                    # -1 marks dead targets
    for r in np.nonzero((out < 0).any(axis=1))[0]:
        src_old = live_rows[r]
        row = out[r]
        have = set(row[row >= 0].tolist())
        for c in np.nonzero(row < 0)[0]:
            dead_old = nb[src_old, c]
            cand = new_of[nb[dead_old]]
            cand = cand[(cand >= 0) & (cand != r)]
            by_d = np.argsort(np.sum(
                (x_np[live_rows[cand]] - x_np[src_old]) ** 2, axis=-1),
                kind="stable")
            pick = next((int(c2) for c2 in cand[by_d]
                         if int(c2) not in have), -1)
            if pick < 0:
                pick = int(row[row >= 0][0]) if have else (r + 1) % n_live
            row[c] = pick
            have.add(pick)
        out[r] = row
    return out.astype(np.int32)
