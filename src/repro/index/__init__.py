from repro.index import graph, ivf

__all__ = ["graph", "ivf"]
