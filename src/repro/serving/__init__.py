from repro.serving.engine import Engine, Retriever, rag_answer

__all__ = ["Engine", "Retriever", "rag_answer"]
