from repro.serving.engine import Engine, rag_answer

__all__ = ["Engine", "rag_answer"]
