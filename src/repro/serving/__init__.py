from repro.serving.cache import CacheStats, ResultCache, query_key
from repro.serving.scheduler import (Engine, RagResult, Request, Response,
                                     Retriever, ServeStats, ServingEngine,
                                     ServingStats, TenantQoS, TokenBucket,
                                     VirtualClock, rag_answer)

__all__ = ["Engine", "RagResult", "Retriever", "ServeStats", "rag_answer",
           "Request", "Response", "ServingEngine", "ServingStats",
           "TenantQoS", "TokenBucket", "VirtualClock",
           "CacheStats", "ResultCache", "query_key"]

# re-exported for serving callers building plans (canonical home: repro.anns)
from repro.anns.api import Database, QueryPlan, SearchResult  # noqa: E402,F401

__all__ += ["Database", "QueryPlan", "SearchResult"]
