from repro.serving.engine import Engine, Retriever, rag_answer

__all__ = ["Engine", "Retriever", "rag_answer"]

# re-exported for serving callers building plans (canonical home: repro.anns)
from repro.anns.api import Database, QueryPlan, SearchResult  # noqa: E402,F401

__all__ += ["Database", "QueryPlan", "SearchResult"]
