"""Query-result cache for the serving engine (see ``serving.scheduler``).

Serving workloads repeat themselves: RAG frontends re-issue the same
question verbatim, dashboards poll fixed probes, and popular queries
dominate open-loop traces.  Re-running the full front → refine → rerank
datapath for an exact repeat buys nothing — the index is deterministic, so
the same query under the same plan against the same index state returns
bit-identical ids and distances.  The cache short-circuits those repeats
at *admission* time, before the request ever reaches the coalescer.

Keying.  An entry is keyed on the triple

  ``(query_key(q), resolved QueryPlan, index generation)``

* ``query_key`` quantizes the query through the SAME level-0 ternary
  residual encoder the index uses for vectors (``core.ternary`` →
  ``core.packing``) and hashes the packed bytes + the f32 scale pair
  (norm, rho).  Two float queries that quantize identically ARE the same
  query as far as a match-on-bytes cache is concerned; conversely any
  bit difference in the packed code misses.  Packing cuts the key to
  ~D/4 bytes, and the encode is a single jitted call per request.
* The *resolved* plan participates so a degraded-QoS request (lower
  ``refine_budget``, see ``scheduler.TokenBucket``) never serves a
  full-service entry or vice versa — results are bit-identical only
  under the plan that produced them.
* The index ``generation`` participates so a mutation epoch can never
  serve stale results (below).

Invalidation.  ``attach(index)`` registers ``_on_mutation`` as a
generation hook on a ``StreamingIndex`` (``add_generation_hook``): every
``insert``/``delete``/``compact``/``rebalance`` bumps the generation and
the hook proactively purges all entries stamped with older generations.
Static/sharded indexes never mutate, so attach is a no-op for them — the
generation in the key (always 0) still guards correctness if a caller
swaps index objects.

Eviction is plain LRU over an ``OrderedDict``; hits refresh recency.
All counters live in ``CacheStats`` so benchmarks and tests can assert
hit/miss/invalidation accounting exactly.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import pack_ternary
from repro.core.ternary import ternary_encode
from repro.obs import trace


@partial(jax.jit)
def _quantize(q: jax.Array):
    """Level-0 ternary encode + bit-pack of one query vector ``(D,)``."""
    tc = ternary_encode(q)
    return pack_ternary(tc.code), tc.norm, tc.rho


def query_key(q) -> bytes:
    """Stable byte key for one query vector: packed level-0 ternary code
    plus the (norm, rho) scale pair as f32 little-endian bytes."""
    packed, norm, rho = _quantize(jnp.asarray(q, jnp.float32))
    return (np.asarray(packed).tobytes()
            + np.float32(norm).tobytes()
            + np.float32(rho).tobytes())


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    inserts: int = 0
    evictions: int = 0
    invalidations: int = 0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "inserts": self.inserts, "evictions": self.evictions,
                "invalidations": self.invalidations}


@dataclass(frozen=True)
class CacheEntry:
    """Cached per-query result: ids and exact distances as host numpy
    copies (detached from any device buffer), plus the QoS class the
    producing batch ran under."""

    ids: np.ndarray
    distances: np.ndarray
    degraded: bool


@dataclass
class ResultCache:
    """LRU result cache keyed on (query bytes, plan, index generation).

    ``hit_latency_us`` is the virtual-clock service time charged to a
    cache hit by the scheduler — hits skip the device datapath entirely,
    so their latency is a (tiny) fixed lookup cost, not a tier ledger.
    """

    capacity: int = 1024
    hit_latency_us: float = 1.0
    stats: CacheStats = field(default_factory=CacheStats)
    _entries: OrderedDict = field(default_factory=OrderedDict)

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, qkey: bytes, plan, generation: int) -> CacheEntry | None:
        key = (qkey, plan, generation)
        entry = self._entries.get(key)
        if entry is None:
            self.stats.misses += 1
            trace.event("cache.miss", track="cache", generation=generation)
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        trace.event("cache.hit", track="cache", generation=generation)
        return entry

    def insert(self, qkey: bytes, plan, generation: int, ids, distances,
               *, degraded: bool = False) -> None:
        key = (qkey, plan, generation)
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        while len(self._entries) >= self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1
            trace.event("cache.evict", track="cache")
        self._entries[key] = CacheEntry(
            ids=np.array(ids), distances=np.array(distances),
            degraded=degraded)
        self.stats.inserts += 1

    def attach(self, index) -> None:
        """Subscribe to ``index`` mutations when it publishes a generation
        hook (``StreamingIndex``, ``TieredIndex``); immutable layouts
        need no hook."""
        hook = getattr(index, "add_generation_hook", None)
        if hook is not None:
            hook(self._on_mutation)

    def _on_mutation(self, index, generation: int) -> None:
        """Mutation fired: purge every entry from an older generation."""
        stale = [k for k in self._entries if k[2] != generation]
        for k in stale:
            del self._entries[k]
        self.stats.invalidations += len(stale)
        if stale:
            trace.event("cache.invalidate", track="cache",
                        generation=generation, purged=len(stale))

    def bind_metrics(self, registry) -> None:
        """Mirror ``CacheStats`` + current size into ``registry`` as the
        ``serving_cache{field=...}`` gauge family, refreshed at export
        time (collector — the lookup/insert hot paths stay untouched)."""
        g = registry.gauge("serving_cache", "result-cache counters",
                           labelnames=("field",))

        def _collect():
            for name, v in self.stats.as_dict().items():
                g.labels(field=name).set(v)
            g.labels(field="size").set(len(self._entries))

        registry.add_collector(_collect)

    def clear(self) -> None:
        self._entries.clear()
