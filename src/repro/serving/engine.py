"""Batched serving engine: continuous-batching decode over a KV cache,
plus the RAG loop that couples the LM with the FaTRQ retriever (paper
Fig. 1: embed prompt → ANNS → feed retrieved context to the LM).

Retrieval goes through the staged ``SearchExecutor`` (anns/executor.py)
with query micro-batching: a serving batch of B prompts is split into
device-sized micro-batches so retrieval latency stays flat as B grows and
the executor's stage counters aggregate into one QueryCost per request
batch.  ``Retriever`` wraps the executor with serving defaults (front
stage, refinement backend, micro-batch size) and keeps a running ledger.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.anns.executor import make_executor
from repro.anns.pipeline import FaTRQIndex
from repro.memory import QueryCost
from repro.models.model_zoo import ModelApi


@dataclass
class ServeStats:
    steps: int = 0
    tokens: int = 0
    retrievals: int = 0


class Engine:
    """Minimal batched decode engine (greedy)."""

    def __init__(self, api: ModelApi, params, *, batch: int, max_len: int,
                 dtype=jnp.float32):
        self.api = api
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.cache = api.init_cache(params, batch, max_len, dtype)
        self.stats = ServeStats()

    def prefill(self, batch_inputs: dict) -> None:
        if self.api.prefill is not None:
            self.cache = self.api.prefill(self.params, batch_inputs,
                                          self.cache)

    def decode(self, tokens: jax.Array, steps: int) -> jax.Array:
        """tokens (B, 1) seed; returns (B, steps) greedy continuations."""
        out = []
        cur = tokens
        for _ in range(steps):
            logits, self.cache = self.api.decode_step(self.params, cur,
                                                      self.cache)
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(cur[:, 0])
            self.stats.steps += 1
            self.stats.tokens += self.batch
        return jnp.stack(out, axis=1)


@dataclass
class Retriever:
    """Serving-side wrapper: staged executor + micro-batching + ledger.

    ``total_cost`` accumulates traffic across requests (capacity-planning
    view); each ``retrieve`` also returns the per-call QueryCost.

    ``shards`` > 1 selects the sharded datapath (``anns.sharding``): the
    database is partitioned across a ``("search",)`` device mesh and each
    retrieval's per-shard ledgers arrive pre-folded under the
    parallel-shard model (max time across shards, summed bytes);
    ``total_cost`` then accumulates those calls serially as usual.
    Requires the IVF front and ``shards`` visible devices.

    ``index`` may also be a ``StreamingIndex`` (``anns.streaming``): live
    traffic keeps retrieving between ``insert``/``delete`` calls through
    its generation-aware datapath (IVF front only), ids stay stable global
    ids across compactions, and delta-list traffic lands on the running
    ledger's distinct ``delta:cxl`` entry.
    """

    index: "FaTRQIndex | StreamingIndex"    # noqa: F821
    front: str = "ivf"
    backend: str = "reference"
    micro_batch: int | None = 8
    shards: int | None = None
    total_cost: QueryCost = field(default_factory=QueryCost)

    def retrieve(self, queries: jax.Array, *, k: int
                 ) -> tuple[jax.Array, QueryCost]:
        from repro.anns.streaming import StreamingIndex
        if isinstance(self.index, StreamingIndex):
            if self.front != "ivf":
                raise ValueError("streaming retrieval supports front='ivf' "
                                 "only")
            ids, cost = self.index.search(queries, k=k,
                                          backend=self.backend,
                                          micro_batch=self.micro_batch,
                                          shards=self.shards)
            self.total_cost.merge(cost)
            return ids, cost
        if self.shards is not None:
            if self.front != "ivf":
                raise ValueError("sharded retrieval supports front='ivf' "
                                 "only")
            from repro.anns.sharding import make_sharded_executor
            ex = make_sharded_executor(self.index, shards=self.shards,
                                       backend=self.backend,
                                       micro_batch=self.micro_batch)
        else:
            ex = make_executor(self.index, front=self.front,
                               backend=self.backend,
                               micro_batch=self.micro_batch)
        ids, cost = ex.search(queries, k=k)
        self.total_cost.merge(cost)
        return ids, cost


def rag_answer(engine: Engine, index: FaTRQIndex, embed_fn, prompt_tokens,
               *, k: int = 5, decode_steps: int = 8,
               retriever: Retriever | None = None, micro_batch: int = 8):
    """One RAG round-trip: embed the prompt, FaTRQ-retrieve top-k context
    ids through the staged executor (micro-batched), prepend them (stub
    tokenization: ids mod vocab), decode."""
    q = embed_fn(prompt_tokens)                       # (B, D) embeddings
    if retriever is None:
        retriever = Retriever(index=index, micro_batch=micro_batch)
    ids, cost = retriever.retrieve(q, k=k)
    engine.stats.retrievals += q.shape[0]
    # stub contextualization: retrieved ids become context tokens
    ctx = (ids % engine.api.cfg.vocab).astype(jnp.int32)
    seed = jnp.concatenate([ctx, prompt_tokens], axis=1)[:, -1:]
    gen = engine.decode(seed, decode_steps)
    return gen, ids, cost
