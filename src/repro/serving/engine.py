"""Batched serving engine: continuous-batching decode over a KV cache,
plus the RAG loop that couples the LM with the FaTRQ retriever (paper
Fig. 1: embed prompt → ANNS → feed retrieved context to the LM).

Retrieval goes through the staged ``SearchExecutor`` (anns/executor.py)
with query micro-batching: a serving batch of B prompts is split into
device-sized micro-batches so retrieval latency stays flat as B grows and
the executor's stage counters aggregate into one QueryCost per request
batch.  ``Retriever`` wraps the executor with serving defaults (front
stage, refinement backend, micro-batch size) and keeps a running ledger.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns.api import Database, QueryPlan, SearchResult
from repro.anns.pipeline import FaTRQIndex
from repro.memory import QueryCost
from repro.models.model_zoo import ModelApi


@dataclass
class ServeStats:
    steps: int = 0
    tokens: int = 0
    retrievals: int = 0


class Engine:
    """Minimal batched decode engine (greedy)."""

    def __init__(self, api: ModelApi, params, *, batch: int, max_len: int,
                 dtype=jnp.float32):
        self.api = api
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.cache = api.init_cache(params, batch, max_len, dtype)
        self.stats = ServeStats()

    def prefill(self, batch_inputs: dict) -> None:
        if self.api.prefill is not None:
            self.cache = self.api.prefill(self.params, batch_inputs,
                                          self.cache)

    def decode(self, tokens: jax.Array, steps: int) -> jax.Array:
        """tokens (B, 1) seed; returns (B, steps) greedy continuations."""
        out = []
        cur = tokens
        for _ in range(steps):
            logits, self.cache = self.api.decode_step(self.params, cur,
                                                      self.cache)
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(cur[:, 0])
            self.stats.steps += 1
            self.stats.tokens += self.batch
        return jnp.stack(out, axis=1)


@dataclass
class Retriever:
    """Serving-side wrapper over the ``anns.api.Database`` handle: one
    default ``QueryPlan`` + a running traffic ledger.

    ``total_cost`` accumulates traffic across requests (capacity-planning
    view); each ``retrieve`` also returns the per-call QueryCost.

    The per-field knobs (``front``/``backend``/``micro_batch``/``shards``)
    are the legacy surface and become the default plan; pass ``plan=`` to
    override them wholesale.  Both registered fronts (IVF and graph) run
    on every index layout; the plan is still validated once against the
    capability registry (invalid plans — unknown names, a shard count or
    front mismatching a wrapped ``ShardedIndex`` — raise ``anns.PlanError``
    at plan time) and compiled once into an executor cached per (index
    generation, plan): repeated ``retrieve`` calls reuse it, and a
    ``StreamingIndex``'s ``insert``/``delete``/``compact``/``rebalance``
    generation bumps invalidate it, including the sharded snapshot behind
    ``shards=S``.

    ``index`` may be a ``FaTRQIndex``, ``ShardedIndex`` or
    ``StreamingIndex`` (or a ready ``Database``): streaming retrieval
    returns stable global ids across compactions and bills delta-list
    traffic to the running ledger's distinct ``delta:cxl`` entry; sharded
    retrieval arrives pre-folded under the parallel-shard model (max time
    across shards, summed bytes).
    """

    index: "FaTRQIndex | StreamingIndex | Database"    # noqa: F821
    front: str = "ivf"
    backend: str = "reference"
    micro_batch: int | None = 8
    shards: int | None = None
    plan: QueryPlan | None = None
    bucket: bool = True
    total_cost: QueryCost = field(default_factory=QueryCost)

    @property
    def db(self) -> Database:
        return Database.wrap(self.index)

    def default_plan(self) -> QueryPlan:
        if self.plan is not None:
            return self.plan
        return QueryPlan(front=self.front, backend=self.backend,
                         shards=self.shards, micro_batch=self.micro_batch)

    def retrieve(self, queries: jax.Array, *, k: int,
                 micro_batch: int | None = None
                 ) -> tuple[jax.Array, QueryCost]:
        """Legacy tuple surface: (Q, k) ids + per-call ledger.
        ``micro_batch`` overrides the plan's batching for this call."""
        res = self.query(queries, k=k, micro_batch=micro_batch)
        return res.ids, res.cost

    def query(self, queries: jax.Array, *, k: int,
              micro_batch: int | None = None) -> SearchResult:
        """Planned retrieval → ``SearchResult`` (ids, exact distances,
        ledger, resolved plan); folds the call into ``total_cost``.

        With ``bucket=True`` (the default) ragged trailing chunks pad to
        the smallest compiled power-of-two bucket ≤ the micro-batch and
        mask the padding with ``qvalid`` — so serving a stream of varying
        batch sizes reuses the handful of bucket traces instead of
        compiling one per distinct remainder (padded rows contribute
        neither candidates nor ledger traffic; results are bit-identical
        to the unpadded path)."""
        res = self.db.query(queries, plan=self.default_plan(), k=k,
                            micro_batch=micro_batch, bucket=self.bucket)
        self.total_cost.merge(res.cost)
        return res


class RagResult(NamedTuple):
    """The full RAG round-trip output: generated tokens, retrieved ids,
    the retrieval traffic ledger, and whether QoS throttling degraded any
    of the batch's retrievals (always False outside a ``ServingEngine``)."""

    tokens: jax.Array     # (B, decode_steps) greedy continuations
    ids: jax.Array        # (B, k) retrieved context ids
    cost: QueryCost       # retrieval ledger for this call
    degraded: bool        # any retrieval ran under a degraded QoS plan


def rag_answer(engine: Engine, index: FaTRQIndex, embed_fn, prompt_tokens,
               *, k: int = 5, decode_steps: int = 8,
               retriever: Retriever | None = None, micro_batch: int = 8,
               plan: QueryPlan | None = None,
               serving=None) -> RagResult:
    """One RAG round-trip: embed the prompt, FaTRQ-retrieve top-k context
    ids through the planned ``Database`` datapath (micro-batched), prepend
    them (stub tokenization: ids mod vocab), decode.

    ``plan`` threads the caller's full ``QueryPlan`` (shards, backend,
    refine budget, ...) into the default retriever — previously a default
    ``Retriever`` was constructed that silently ignored any such
    configuration.  Pass ``retriever`` instead to keep a running ledger
    across calls, or ``serving`` (a ``serving.scheduler.ServingEngine``)
    to route retrieval through the continuous-batching scheduler — QoS
    degradation and cache hits then surface in the returned ``RagResult``
    (``degraded`` flag; cache hits contribute no ledger traffic).  The
    three are mutually exclusive.

    Returns a ``RagResult`` named tuple — the retrieval ``QueryCost`` and
    the ``degraded`` flag ride along with tokens and ids, so callers
    (e.g. ``launch.serve``) can bill retrieval traffic per request
    without reaching into retriever internals."""
    q = embed_fn(prompt_tokens)                       # (B, D) embeddings
    if serving is not None:
        if retriever is not None or plan is not None:
            raise ValueError("pass serving= alone — a ServingEngine "
                             "carries its own plan and QoS config")
        resp = serving.serve(q, k=k)
        ids = jnp.asarray(np.stack([r.ids for r in resp]))
        cost = QueryCost()
        seen_batches = set()
        for r in resp:
            if r.cost is not None and r.batch not in seen_batches:
                seen_batches.add(r.batch)
                cost.merge(r.cost)
        degraded = any(r.degraded for r in resp)
    else:
        if retriever is None:
            if plan is not None and plan.micro_batch is None:
                plan = dataclasses.replace(plan, micro_batch=micro_batch)
            retriever = Retriever(index=index, micro_batch=micro_batch,
                                  plan=plan)
        elif plan is not None:
            raise ValueError("pass plan= or retriever=, not both — a "
                             "Retriever carries its own plan")
        ids, cost = retriever.retrieve(q, k=k)
        degraded = False
    engine.stats.retrievals += q.shape[0]
    # stub contextualization: retrieved ids become context tokens
    ctx = (ids % engine.api.cfg.vocab).astype(jnp.int32)
    seed = jnp.concatenate([ctx, prompt_tokens], axis=1)[:, -1:]
    gen = engine.decode(seed, decode_steps)
    return RagResult(tokens=gen, ids=ids, cost=cost, degraded=degraded)
