"""Batched serving engine: continuous-batching decode over a KV cache,
plus the RAG loop that couples the LM with the FaTRQ retriever (paper
Fig. 1: embed prompt → ANNS → feed retrieved context to the LM).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns.pipeline import FaTRQIndex, search
from repro.models.model_zoo import ModelApi


@dataclass
class ServeStats:
    steps: int = 0
    tokens: int = 0
    retrievals: int = 0


class Engine:
    """Minimal batched decode engine (greedy)."""

    def __init__(self, api: ModelApi, params, *, batch: int, max_len: int,
                 dtype=jnp.float32):
        self.api = api
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.cache = api.init_cache(params, batch, max_len, dtype)
        self.stats = ServeStats()

    def prefill(self, batch_inputs: dict) -> None:
        if self.api.prefill is not None:
            self.cache = self.api.prefill(self.params, batch_inputs,
                                          self.cache)

    def decode(self, tokens: jax.Array, steps: int) -> jax.Array:
        """tokens (B, 1) seed; returns (B, steps) greedy continuations."""
        out = []
        cur = tokens
        for _ in range(steps):
            logits, self.cache = self.api.decode_step(self.params, cur,
                                                      self.cache)
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(cur[:, 0])
            self.stats.steps += 1
            self.stats.tokens += self.batch
        return jnp.stack(out, axis=1)


def rag_answer(engine: Engine, index: FaTRQIndex, embed_fn, prompt_tokens,
               *, k: int = 5, decode_steps: int = 8):
    """One RAG round-trip: embed the prompt, FaTRQ-retrieve top-k context
    ids, prepend them (stub tokenization: ids mod vocab), decode."""
    q = embed_fn(prompt_tokens)                       # (B, D) embeddings
    ids, cost = search(index, q, k=k)
    engine.stats.retrievals += q.shape[0]
    # stub contextualization: retrieved ids become context tokens
    ctx = (ids % engine.api.cfg.vocab).astype(jnp.int32)
    seed = jnp.concatenate([ctx, prompt_tokens], axis=1)[:, -1:]
    gen = engine.decode(seed, decode_steps)
    return gen, ids, cost
