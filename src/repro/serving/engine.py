"""Deprecated shim — the serving entry point is ``repro.serving.scheduler``.

The batched decode engine, ``Retriever`` and ``rag_answer`` moved into
``serving.scheduler`` next to the continuous-batching ``ServingEngine``
they feed, so the package has ONE serving entry point.  This module
re-exports them for pre-move imports; new code should import from
``repro.serving`` (the package facade) or ``repro.serving.scheduler``.
"""

from repro.serving.scheduler import (Engine, RagResult, Retriever,
                                     ServeStats, rag_answer)

__all__ = ["Engine", "RagResult", "Retriever", "ServeStats", "rag_answer"]
