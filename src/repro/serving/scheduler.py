"""Continuous-batching serving engine over ``anns.api.Database``.

The query layer (PR 5-7) answers *one batch at a time*: callers hand
``db.query`` a query stack and block until the staged executor finishes.
A serving frontend sees a different shape of work — an open-loop stream
of single-query requests with deadlines and tenants — and pays for the
mismatch twice: per-request dispatch recompiles nothing but still runs
the datapath at batch size 1 (device utilization ∝ batch size), and a
hot tenant can starve everyone else out of the refine budget.

``ServingEngine`` closes the gap with four cooperating pieces:

* **Admission scheduler** — requests enter a deadline-ordered (EDF)
  admission queue under a deterministic virtual clock (microseconds).
  The engine is a discrete-event simulator over that clock: identical
  (seed, arrival trace) inputs produce identical batch boundaries,
  which is what makes the scheduler testable at all.
* **Coalescer** — admitted requests group by service class
  ``(k, degraded)``; a class's micro-batch closes when it reaches
  ``max_batch`` or its oldest member has waited ``max_wait_us``.
  Batches pad to the compiled power-of-two buckets
  (``executor.bucket_for`` / ``pad_chunk``), so the plan-keyed executor
  cache is reused across every batch size — the engine never triggers
  a recompile at dispatch time.
* **Double-buffered dispatch** — on layouts with a front/refine split
  (``CompiledPlan.supports_split``), batch N+1's candidate-generation
  stage (``run_front``) is enqueued *before* batch N's refine + rerank
  (``run_finish``) is retired, overlapping the HBM-resident front with
  the CXL/SSD-bound refine exactly as the paper's pipeline does for
  levels.  The virtual-clock model mirrors that: a front unit and a
  refine unit with independent free times, each batch's stage times
  taken from its own ledger (front = HBM tier seconds, refine = the
  rest).  The fused sharded body has no split point; it dispatches
  whole batches on a single serial unit.
* **Per-tenant QoS** — each tenant owns a token bucket
  (``rate_rps``/``burst``).  A request arriving to an empty bucket is
  *degraded, not rejected*: it runs under a reduced
  ``QueryPlan.refine_budget`` (÷ ``degrade_factor``, floored at k) and
  its response carries ``degraded=True``.  Throttling trades recall
  for admission — the starved tenant still progresses.
* **Result cache** (``serving.cache.ResultCache``) — admission first
  probes the cache under the exact class plan the request would run
  with; hits bypass the coalescer entirely and are charged a fixed
  ``hit_latency_us``.  Entries key on (quantized query bytes, resolved
  plan, index generation) and are purged by ``StreamingIndex``
  mutations via the generation hook.

Bit-identity: batches are formed only within a service class, padded
rows are masked out of candidates and counters by ``qvalid``, and the
datapath is per-query deterministic — so every response's ids,
distances, and the summed ledger are bit-identical to sequential
``db.query`` calls with the same per-request plans (pinned in
``tests/test_serving.py``).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns.api import Database, QueryPlan, SearchResult
from repro.anns.executor import bucket_for, pad_chunk
from repro.anns.pipeline import FaTRQIndex
from repro.memory.tiers import QueryCost, Tier
from repro.models.model_zoo import ModelApi
from repro.obs import metrics as obs_metrics, trace
from repro.obs.metrics import MetricsRegistry
from repro.serving.cache import ResultCache, query_key

__all__ = ["Request", "Response", "TenantQoS", "TokenBucket",
           "VirtualClock", "ServingEngine", "ServingStats",
           "Engine", "ServeStats", "Retriever", "RagResult", "rag_answer"]


@dataclass(frozen=True)
class Request:
    """One serving request: a single query vector plus scheduling
    metadata.  ``rid`` is assigned by the engine (monotonic, arrival
    order) when left ``None``."""

    query: object                      # (D,) float vector
    tenant: str = "default"
    k: int | None = None               # None → plan/config final_k
    arrival_us: float = 0.0
    deadline_us: float = math.inf
    rid: int | None = None


@dataclass
class Response:
    """One completed request.  ``cost`` is the ledger of the *batch* the
    request rode in (shared object across its co-batched peers; None for
    cache hits, which never touch the datapath)."""

    rid: int
    tenant: str
    ids: np.ndarray
    distances: np.ndarray
    degraded: bool
    cache_hit: bool
    arrival_us: float
    admit_us: float
    done_us: float
    batch: int | None
    cost: QueryCost | None

    @property
    def latency_us(self) -> float:
        return self.done_us - self.arrival_us


@dataclass
class VirtualClock:
    """Deterministic microsecond clock; only ever advances."""

    now_us: float = 0.0

    def advance_to(self, t_us: float) -> None:
        self.now_us = max(self.now_us, t_us)


@dataclass
class TokenBucket:
    """Standard token bucket in request units, refilled on observation."""

    rate_per_s: float
    burst: float
    tokens: float = 0.0
    last_us: float = 0.0

    def __post_init__(self):
        self.tokens = self.burst

    def _refill(self, now_us: float) -> None:
        if now_us > self.last_us:
            self.tokens = min(
                self.burst,
                self.tokens + (now_us - self.last_us) * self.rate_per_s / 1e6)
            self.last_us = now_us

    def peek(self, now_us: float) -> bool:
        """True when a full-service token is available (does not consume)."""
        self._refill(now_us)
        return self.tokens >= 1.0

    def take(self, now_us: float) -> None:
        self._refill(now_us)
        self.tokens -= 1.0


@dataclass(frozen=True)
class TenantQoS:
    """Per-tenant service contract: sustained full-service rate and burst
    allowance.  ``rate_rps=None`` means unthrottled (never degraded)."""

    rate_rps: float | None = None
    burst: float = 8.0


@dataclass
class ServingStats:
    requests: int = 0
    batches: int = 0
    cache_hits: int = 0
    degraded: int = 0
    padded_slots: int = 0

    def as_dict(self) -> dict:
        return {"requests": self.requests, "batches": self.batches,
                "cache_hits": self.cache_hits, "degraded": self.degraded,
                "padded_slots": self.padded_slots}


@dataclass
class _Admitted:
    """A request past admission, waiting in its class queue."""

    deadline_us: float
    arrival_us: float
    rid: int
    req: Request
    admit_us: float
    qkey: bytes | None
    degraded: bool


@dataclass
class _Inflight:
    """A batch whose front stage has been dispatched but whose refine has
    not been retired yet (double buffering holds at most one)."""

    bid: int
    batch: list
    cp: object
    qpad: object
    cand: object
    n: int
    dispatch_us: float
    degraded: bool


class ServingEngine:
    """Continuous-batching request scheduler over one ``Database``.

    Parameters
    ----------
    index : FaTRQIndex | ShardedIndex | StreamingIndex | Database
    plan : QueryPlan | None — base plan; ``micro_batch`` is forced to
        ``max_batch`` so coalesced batches are single executor chunks.
    max_batch : coalescer close size (and compiled micro-batch).
    max_wait_us : coalescer close age for a non-full batch.
    qos : dict[str, TenantQoS] — per-tenant contracts; missing tenants
        fall back to ``default_qos`` (None = unthrottled).
    degrade_factor : refine-budget divisor for throttled requests.
    cache : ResultCache | None — attach a result cache.
    batching : False degenerates to one-request batches (the baseline
        the benchmark compares against).
    overlap : False disables double buffering (serial timing model).
    dispatch_overhead_us : fixed host cost charged per dispatched batch
        in the virtual timing model — the submit + sync round trip the
        tier ledger (pure memory traffic) cannot see.  This is the cost
        coalescing amortizes: one-request batches pay it per query.
    """

    def __init__(self, index, *, plan: QueryPlan | None = None,
                 max_batch: int = 8, max_wait_us: float = 200.0,
                 qos: dict | None = None,
                 default_qos: TenantQoS | None = None,
                 degrade_factor: int = 4,
                 cache: ResultCache | None = None,
                 batching: bool = True, overlap: bool = True,
                 dispatch_overhead_us: float = 50.0,
                 mesh=None, tracer=None):
        self.db = index if isinstance(index, Database) else Database.wrap(index)
        if not batching:
            max_batch, max_wait_us = 1, 0.0
        self.max_batch = int(max_batch)
        self.max_wait_us = float(max_wait_us)
        base = plan or QueryPlan()
        base = dataclasses.replace(base, micro_batch=self.max_batch)
        self.base_plan = self.db.validate(base)
        self.qos = dict(qos or {})
        self.default_qos = default_qos
        self.degrade_factor = int(degrade_factor)
        self.cache = cache
        self.overlap = bool(overlap)
        self.dispatch_overhead_us = float(dispatch_overhead_us)
        self.mesh = mesh
        if cache is not None:
            cache.attach(self.db.index)

        self.clock = VirtualClock()
        self.stats = ServingStats()
        self.total_cost = QueryCost()
        self.batch_log: list[tuple] = []   # (bid, dispatch_us, rids)
        self._buckets: dict[str, TokenBucket] = {}
        self._queues: dict[tuple, list] = {}    # (k, degraded) -> [_Admitted]
        self._plan_cache: dict[tuple, QueryPlan] = {}
        self._inflight: _Inflight | None = None
        self._next_rid = 0
        # virtual pipeline units (see module docstring)
        self._front_free_us = 0.0
        self._refine_free_us = 0.0
        self._busy_free_us = 0.0

        # observability: a per-engine metrics registry (activated around
        # ``run`` so executor-level series like fatrq_model_drift_ratio
        # aggregate here, not in the process default) + an optional
        # tracer whose virtual clock is wired to the engine's.
        self.registry = MetricsRegistry()
        self.tracer = tracer
        if tracer is not None and tracer.virtual_clock is None:
            tracer.virtual_clock = lambda: self.clock.now_us
        self._m_requests = self.registry.counter(
            "serving_requests_total", "requests admitted, by tenant",
            labelnames=("tenant",))
        self._m_throttled = self.registry.counter(
            "serving_throttled_total",
            "requests degraded by QoS throttling, by tenant",
            labelnames=("tenant",))
        self._m_queue_wait = self.registry.histogram(
            "serving_queue_wait_us",
            "virtual µs between admission and batch dispatch")
        self._m_occupancy = self.registry.histogram(
            "serving_batch_occupancy", "requests per dispatched batch",
            buckets=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0))
        self.registry.add_collector(self._mirror_stats)
        if cache is not None:
            cache.bind_metrics(self.registry)

    def _mirror_stats(self) -> None:
        """Export-time collector: ``ServingStats`` snapshot → the
        ``serving_stats{field=...}`` gauge family."""
        g = self.registry.gauge("serving_stats", "ServingStats snapshot",
                                labelnames=("field",))
        for name, v in self.stats.as_dict().items():
            g.labels(field=name).set(v)

    def metrics(self) -> dict:
        """One flat ``{"name{labels}": value}`` dict unifying scheduler
        counters, ServingStats, per-tenant throttling, cache stats, and
        any datapath series recorded while ``run`` was active."""
        return self.registry.flat()

    # -- QoS ---------------------------------------------------------------

    def _bucket(self, tenant: str) -> TokenBucket | None:
        contract = self.qos.get(tenant, self.default_qos)
        if contract is None or contract.rate_rps is None:
            return None
        bucket = self._buckets.get(tenant)
        if bucket is None:
            bucket = TokenBucket(rate_per_s=contract.rate_rps,
                                 burst=contract.burst,
                                 last_us=self.clock.now_us)
            self._buckets[tenant] = bucket
        return bucket

    def _class_plan(self, k: int, degraded: bool) -> QueryPlan:
        """The resolved plan a (k, degraded) service class runs under.
        Degraded classes trade refine depth (÷ degrade_factor, floored at
        k so the rerank stage stays well-formed) for admission."""
        key = (k, degraded)
        plan = self._plan_cache.get(key)
        if plan is None:
            rb = self.base_plan.refine_budget
            if degraded:
                rb = max(k, rb // self.degrade_factor)
            plan = self.db.validate(dataclasses.replace(
                self.base_plan, k=k, refine_budget=rb))
            self._plan_cache[key] = plan
        return plan

    # -- admission ---------------------------------------------------------

    def _admit(self, req: Request, responses: list) -> None:
        now = self.clock.now_us
        self.stats.requests += 1
        self._m_requests.labels(tenant=req.tenant).inc()
        rk = req.k or self.base_plan.k
        bucket = self._bucket(req.tenant)
        degraded = bucket is not None and not bucket.peek(now)
        trace.event("serve.admit", track="sched", rid=req.rid,
                    tenant=req.tenant, k=rk, degraded=degraded)
        if degraded:
            self._m_throttled.labels(tenant=req.tenant).inc()
            trace.event("serve.throttle", track="sched", rid=req.rid,
                        tenant=req.tenant)
        plan = self._class_plan(rk, degraded)
        qkey = None
        if self.cache is not None:
            qkey = query_key(req.query)
            entry = self.cache.lookup(qkey, plan, self.db.generation)
            if entry is not None:
                self.stats.cache_hits += 1
                if degraded:
                    self.stats.degraded += 1
                trace.event("serve.cache_hit", track="sched", rid=req.rid,
                            tenant=req.tenant)
                responses.append(Response(
                    rid=req.rid, tenant=req.tenant,
                    ids=entry.ids.copy(), distances=entry.distances.copy(),
                    degraded=degraded, cache_hit=True,
                    arrival_us=req.arrival_us, admit_us=now,
                    done_us=now + self.cache.hit_latency_us,
                    batch=None, cost=None))
                return
        if degraded:
            self.stats.degraded += 1
        elif bucket is not None:
            bucket.take(now)    # full service consumes; misses only
        self._queues.setdefault((rk, degraded), []).append(_Admitted(
            deadline_us=req.deadline_us, arrival_us=req.arrival_us,
            rid=req.rid, req=req, admit_us=now, qkey=qkey,
            degraded=degraded))

    # -- coalescing + dispatch ---------------------------------------------

    def _dispatch_ready(self, responses: list, *, drain: bool = False) -> None:
        now = self.clock.now_us
        for class_key in list(self._queues):
            queue = self._queues[class_key]
            while queue:
                oldest = min(a.admit_us for a in queue)
                full = len(queue) >= self.max_batch
                aged = now >= oldest + self.max_wait_us
                if not (full or aged or drain):
                    break
                # EDF within the class: earliest deadline first, then
                # arrival, then rid — a total, deterministic order.
                queue.sort(key=lambda a: (a.deadline_us, a.arrival_us, a.rid))
                batch, self._queues[class_key] = (
                    queue[:self.max_batch], queue[self.max_batch:])
                queue = self._queues[class_key]
                self._dispatch(class_key, batch, responses)
            if not self._queues[class_key]:
                del self._queues[class_key]

    def _dispatch(self, class_key: tuple, batch: list, responses: list) -> None:
        rk, degraded = class_key
        bid = len(self.batch_log)
        now = self.clock.now_us
        self.batch_log.append((bid, now, tuple(a.rid for a in batch)))
        self.stats.batches += 1
        self._m_occupancy.observe(len(batch))
        for a in batch:
            self._m_queue_wait.observe(now - a.admit_us)
        trace.event("serve.dispatch", track="sched", bid=bid, k=rk,
                    degraded=degraded, n=len(batch),
                    rids=[a.rid for a in batch])
        cp = self.db.compiled(self._class_plan(rk, degraded), mesh=self.mesh)
        q = jnp.stack([jnp.asarray(a.req.query, jnp.float32) for a in batch])
        n = q.shape[0]
        if self.overlap and cp.supports_split:
            bucket = bucket_for(n, self.max_batch)
            qpad, qvalid = pad_chunk(q, bucket)
            self.stats.padded_slots += bucket - n
            cand = cp.run_front(qpad, qvalid=qvalid)
            # retire the PREVIOUS batch's refine only after this front is
            # enqueued — the double buffer.
            self._retire_inflight(responses)
            self._inflight = _Inflight(bid=bid, batch=batch, cp=cp,
                                       qpad=qpad, cand=cand, n=n,
                                       dispatch_us=now, degraded=degraded)
        else:
            self._retire_inflight(responses)
            res = cp.execute(q, pad=True)   # executor buckets internally
            self.stats.padded_slots += bucket_for(n, self.max_batch) - n
            self._complete(bid, batch, cp, res, n, now, degraded, responses,
                           split=False)

    def _retire_inflight(self, responses: list) -> None:
        fl = self._inflight
        if fl is None:
            return
        self._inflight = None
        res = fl.cp.run_finish(fl.qpad, fl.cand)
        self._complete(fl.bid, fl.batch, fl.cp, res, fl.n, fl.dispatch_us,
                       fl.degraded, responses, split=True)

    # -- completion --------------------------------------------------------

    def _complete(self, bid: int, batch: list, cp, res, n: int,
                  dispatch_us: float, degraded: bool, responses: list,
                  *, split: bool) -> None:
        cost = res.cost
        front_s = cost.tier_seconds(Tier.HBM)
        # per-batch host dispatch round trip rides on the front stage —
        # this is the fixed cost the coalescer amortizes over the batch
        f_us = front_s * 1e6 + self.dispatch_overhead_us
        r_us = max(cost.total_seconds() - front_s, 0.0) * 1e6
        tr = trace.active()
        if self.overlap and split:
            start_f = max(dispatch_us, self._front_free_us)
            front_done = start_f + f_us
            self._front_free_us = front_done
            start_r = max(front_done, self._refine_free_us)
            done = start_r + r_us
            self._refine_free_us = done
            if tr is not None:
                # the units' occupancy is known only now — spans are
                # back-stamped with explicit virtual intervals
                sp = tr.add_span("serve.batch", track="sched",
                                 virtual_start_us=dispatch_us,
                                 virtual_end_us=done, bid=bid, n=n,
                                 degraded=degraded, split=True)
                tr.add_span("serve.front", track="unit:front",
                            virtual_start_us=start_f,
                            virtual_end_us=front_done,
                            parent=sp.sid, bid=bid)
                tr.add_span("serve.refine", track="unit:refine",
                            virtual_start_us=start_r, virtual_end_us=done,
                            parent=sp.sid, bid=bid)
        else:
            start = max(dispatch_us, self._busy_free_us)
            done = start + f_us + r_us
            self._busy_free_us = done
            if tr is not None:
                sp = tr.add_span("serve.batch", track="sched",
                                 virtual_start_us=dispatch_us,
                                 virtual_end_us=done, bid=bid, n=n,
                                 degraded=degraded, split=False)
                tr.add_span("serve.dispatch.serial", track="unit:serial",
                            virtual_start_us=start, virtual_end_us=done,
                            parent=sp.sid, bid=bid)
        self.total_cost.merge(cost)
        ids = np.asarray(res.ids[:n])
        dists = np.asarray(res.distances[:n])
        for i, adm in enumerate(batch):
            if self.cache is not None and adm.qkey is not None:
                self.cache.insert(adm.qkey, cp.plan, cp.generation,
                                  ids[i], dists[i], degraded=degraded)
            responses.append(Response(
                rid=adm.rid, tenant=adm.req.tenant,
                ids=ids[i], distances=dists[i],
                degraded=degraded, cache_hit=False,
                arrival_us=adm.arrival_us, admit_us=adm.admit_us,
                done_us=done, batch=bid, cost=cost))

    # -- event loop --------------------------------------------------------

    def run(self, requests: list) -> list:
        """Run a full request trace to drain; responses in rid order.

        Discrete-event loop: the clock jumps between arrival instants and
        coalescer close deadlines — nothing happens between events, so
        the simulation is exact and deterministic.

        The engine's metrics registry is active for the duration (and the
        engine's tracer, when one was attached), so datapath series and
        spans recorded deep in the executor land with the engine's own.
        """
        with contextlib.ExitStack() as stack:
            stack.enter_context(obs_metrics.use(self.registry))
            if self.tracer is not None:
                stack.enter_context(trace.use(self.tracer))
            return self._run(requests)

    def _run(self, requests: list) -> list:
        pending = sorted(
            requests,
            key=lambda r: (r.arrival_us,
                           r.rid if r.rid is not None else math.inf))
        pending = [r if r.rid is not None
                   else dataclasses.replace(r, rid=self._fresh_rid())
                   for r in pending]
        responses: list[Response] = []
        i = 0
        while i < len(pending) or self._queues:
            times = []
            if i < len(pending):
                times.append(pending[i].arrival_us)
            for queue in self._queues.values():
                oldest = min(a.admit_us for a in queue)
                times.append(oldest + self.max_wait_us)
            self.clock.advance_to(min(times))
            now = self.clock.now_us
            arrivals = []
            while i < len(pending) and pending[i].arrival_us <= now:
                arrivals.append(pending[i])
                i += 1
            # EDF admission order at this instant.
            arrivals.sort(key=lambda r: (r.deadline_us, r.arrival_us, r.rid))
            for req in arrivals:
                self._admit(req, responses)
            self._dispatch_ready(responses)
        self._dispatch_ready(responses, drain=True)
        self._retire_inflight(responses)
        responses.sort(key=lambda r: r.rid)
        return responses

    def _fresh_rid(self) -> int:
        rid = self._next_rid
        self._next_rid += 1
        return rid

    def serve(self, queries, *, k: int | None = None,
              tenant: str = "default") -> list:
        """Convenience: submit one request per row at the current clock
        instant and run to drain.  Responses come back in input order."""
        queries = jnp.asarray(queries, jnp.float32)
        now = self.clock.now_us
        reqs = [Request(query=queries[i], tenant=tenant, k=k,
                        arrival_us=now, rid=self._fresh_rid())
                for i in range(queries.shape[0])]
        return self.run(reqs)


# ----------------------------------------------------------- RAG serving
# The LM-facing half of the serving layer (formerly ``serving.engine``,
# absorbed here so the package has ONE serving entry point): a minimal
# batched decode engine, the planned ``Retriever`` wrapper over
# ``Database``, and the ``rag_answer`` round-trip coupling the two
# (paper Fig. 1: embed prompt → ANNS → feed retrieved context to the LM).


@dataclass
class ServeStats:
    steps: int = 0
    tokens: int = 0
    retrievals: int = 0


class Engine:
    """Minimal batched decode engine (greedy)."""

    def __init__(self, api: ModelApi, params, *, batch: int, max_len: int,
                 dtype=jnp.float32):
        self.api = api
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.cache = api.init_cache(params, batch, max_len, dtype)
        self.stats = ServeStats()

    def prefill(self, batch_inputs: dict) -> None:
        if self.api.prefill is not None:
            self.cache = self.api.prefill(self.params, batch_inputs,
                                          self.cache)

    def decode(self, tokens: jax.Array, steps: int) -> jax.Array:
        """tokens (B, 1) seed; returns (B, steps) greedy continuations."""
        out = []
        cur = tokens
        for _ in range(steps):
            logits, self.cache = self.api.decode_step(self.params, cur,
                                                      self.cache)
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(cur[:, 0])
            self.stats.steps += 1
            self.stats.tokens += self.batch
        return jnp.stack(out, axis=1)


@dataclass
class Retriever:
    """Serving-side wrapper over the ``anns.api.Database`` handle: one
    default ``QueryPlan`` + a running traffic ledger.

    ``total_cost`` accumulates traffic across requests (capacity-planning
    view); each ``retrieve`` also returns the per-call QueryCost.

    The per-field knobs (``front``/``backend``/``micro_batch``/``shards``)
    are the legacy surface and become the default plan; pass ``plan=`` to
    override them wholesale.  Both registered fronts (IVF and graph) run
    on every index layout; the plan is still validated once against the
    capability registry (invalid plans — unknown names, a shard count or
    front mismatching a wrapped ``ShardedIndex`` — raise ``anns.PlanError``
    at plan time) and compiled once into an executor cached per (index
    generation, plan): repeated ``retrieve`` calls reuse it, and a
    ``StreamingIndex``'s ``insert``/``delete``/``compact``/``rebalance``
    generation bumps invalidate it, including the sharded snapshot behind
    ``shards=S``.

    ``index`` may be a ``FaTRQIndex``, ``ShardedIndex``, ``StreamingIndex``
    or ``TieredIndex`` (or a ready ``Database``): streaming retrieval
    returns stable global ids across compactions and bills delta-list
    traffic to the running ledger's distinct ``delta:cxl`` entry; sharded
    retrieval arrives pre-folded under the parallel-shard model (max time
    across shards, summed bytes); tiered retrieval bills hot/cold
    placement traffic to ``hot:hbm``/``cold:ssd`` and its
    ``rebalance_tiers()`` generation bumps invalidate cached executors
    exactly like streaming mutations do.
    """

    index: "FaTRQIndex | StreamingIndex | Database"    # noqa: F821
    front: str = "ivf"
    backend: str = "reference"
    micro_batch: int | None = 8
    shards: int | None = None
    plan: QueryPlan | None = None
    bucket: bool = True
    total_cost: QueryCost = field(default_factory=QueryCost)

    @property
    def db(self) -> Database:
        return Database.wrap(self.index)

    def default_plan(self) -> QueryPlan:
        if self.plan is not None:
            return self.plan
        return QueryPlan(front=self.front, backend=self.backend,
                         shards=self.shards, micro_batch=self.micro_batch)

    def retrieve(self, queries: jax.Array, *, k: int,
                 micro_batch: int | None = None
                 ) -> tuple[jax.Array, QueryCost]:
        """Legacy tuple surface: (Q, k) ids + per-call ledger.
        ``micro_batch`` overrides the plan's batching for this call."""
        res = self.query(queries, k=k, micro_batch=micro_batch)
        return res.ids, res.cost

    def query(self, queries: jax.Array, *, k: int,
              micro_batch: int | None = None) -> SearchResult:
        """Planned retrieval → ``SearchResult`` (ids, exact distances,
        ledger, resolved plan); folds the call into ``total_cost``.

        With ``bucket=True`` (the default) ragged trailing chunks pad to
        the smallest compiled power-of-two bucket ≤ the micro-batch and
        mask the padding with ``qvalid`` — so serving a stream of varying
        batch sizes reuses the handful of bucket traces instead of
        compiling one per distinct remainder (padded rows contribute
        neither candidates nor ledger traffic; results are bit-identical
        to the unpadded path)."""
        res = self.db.query(queries, plan=self.default_plan(), k=k,
                            micro_batch=micro_batch, bucket=self.bucket)
        self.total_cost.merge(res.cost)
        return res


class RagResult(NamedTuple):
    """The full RAG round-trip output: generated tokens, retrieved ids,
    the retrieval traffic ledger, and whether QoS throttling degraded any
    of the batch's retrievals (always False outside a ``ServingEngine``)."""

    tokens: jax.Array     # (B, decode_steps) greedy continuations
    ids: jax.Array        # (B, k) retrieved context ids
    cost: QueryCost       # retrieval ledger for this call
    degraded: bool        # any retrieval ran under a degraded QoS plan


def rag_answer(engine: Engine, index: FaTRQIndex, embed_fn, prompt_tokens,
               *, k: int = 5, decode_steps: int = 8,
               retriever: Retriever | None = None, micro_batch: int = 8,
               plan: QueryPlan | None = None,
               serving=None) -> RagResult:
    """One RAG round-trip: embed the prompt, FaTRQ-retrieve top-k context
    ids through the planned ``Database`` datapath (micro-batched), prepend
    them (stub tokenization: ids mod vocab), decode.

    ``plan`` threads the caller's full ``QueryPlan`` (shards, backend,
    refine budget, ...) into the default retriever — previously a default
    ``Retriever`` was constructed that silently ignored any such
    configuration.  Pass ``retriever`` instead to keep a running ledger
    across calls, or ``serving`` (a ``ServingEngine``) to route retrieval
    through the continuous-batching scheduler — QoS degradation and cache
    hits then surface in the returned ``RagResult`` (``degraded`` flag;
    cache hits contribute no ledger traffic).  The three are mutually
    exclusive.

    Returns a ``RagResult`` named tuple — the retrieval ``QueryCost`` and
    the ``degraded`` flag ride along with tokens and ids, so callers
    (e.g. ``launch.serve``) can bill retrieval traffic per request
    without reaching into retriever internals."""
    q = embed_fn(prompt_tokens)                       # (B, D) embeddings
    if serving is not None:
        if retriever is not None or plan is not None:
            raise ValueError("pass serving= alone — a ServingEngine "
                             "carries its own plan and QoS config")
        resp = serving.serve(q, k=k)
        ids = jnp.asarray(np.stack([r.ids for r in resp]))
        cost = QueryCost()
        seen_batches = set()
        for r in resp:
            if r.cost is not None and r.batch not in seen_batches:
                seen_batches.add(r.batch)
                cost.merge(r.cost)
        degraded = any(r.degraded for r in resp)
    else:
        if retriever is None:
            if plan is not None and plan.micro_batch is None:
                plan = dataclasses.replace(plan, micro_batch=micro_batch)
            retriever = Retriever(index=index, micro_batch=micro_batch,
                                  plan=plan)
        elif plan is not None:
            raise ValueError("pass plan= or retriever=, not both — a "
                             "Retriever carries its own plan")
        ids, cost = retriever.retrieve(q, k=k)
        degraded = False
    engine.stats.retrievals += q.shape[0]
    # stub contextualization: retrieved ids become context tokens
    ctx = (ids % engine.api.cfg.vocab).astype(jnp.int32)
    seed = jnp.concatenate([ctx, prompt_tokens], axis=1)[:, -1:]
    gen = engine.decode(seed, decode_steps)
    return RagResult(tokens=gen, ids=ids, cost=cost, degraded=degraded)
