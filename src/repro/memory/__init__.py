from repro.memory.layout import RecordLayout
from repro.memory.placement import (TIER_COLD, TIER_HOT, TIER_NAMES,
                                    TIER_WARM, HeatTracker, TieredConfig,
                                    occupancy, plan_migration,
                                    plan_placement)
from repro.memory.tiers import TABLE_I, QueryCost, Tier, TierSpec, Traffic

__all__ = ["RecordLayout", "TABLE_I", "QueryCost", "Tier", "TierSpec",
           "Traffic", "TIER_HOT", "TIER_WARM", "TIER_COLD", "TIER_NAMES",
           "HeatTracker", "TieredConfig", "occupancy", "plan_migration",
           "plan_placement"]
