from repro.memory.layout import RecordLayout
from repro.memory.tiers import TABLE_I, QueryCost, Tier, TierSpec, Traffic

__all__ = ["RecordLayout", "TABLE_I", "QueryCost", "Tier", "TierSpec",
           "Traffic"]
