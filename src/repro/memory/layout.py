"""Far-memory record layout (paper Fig. 3 / §III-D).

Fast memory  : PQ codes (N, M) uint8 + PQ codebooks + IVF/graph index.
Far memory   : per record, per TRQ level — packed ternary code
               (⌈D/5⌉ B) + 8 B scalars (⟨x_c,δ⟩ f32, ‖δ‖² f32).
Storage(SSD) : full-precision vectors (D×4 B), touched only by survivors.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.packing import packed_size


@dataclass(frozen=True)
class RecordLayout:
    dim: int
    pq_m: int
    levels: int = 1
    store_rho: bool = False   # +4 B/level enables the provable Cauchy bound

    @property
    def fast_bytes(self) -> int:
        """Per-record fast-memory payload (PQ code)."""
        return self.pq_m

    @property
    def far_bytes(self) -> int:
        scalars = 12 if self.store_rho else 8
        return self.levels * packed_size(self.dim) + scalars

    @property
    def ssd_bytes(self) -> int:
        return self.dim * 4

    def describe(self) -> dict[str, int]:
        return {"fast_B": self.fast_bytes, "far_B": self.far_bytes,
                "ssd_B": self.ssd_bytes}
