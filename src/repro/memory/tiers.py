"""Tiered-memory cost model (paper Table I) + traffic accounting.

The container has no CXL device or SSD on the hot path, so end-to-end
throughput claims (Fig. 6) are reproduced through this calibrated analytical
model, exactly the constants the paper simulates with (Ramulator DDR5 +
Samsung 990 Pro + Marvell Structera):

  DRAM  : DDR5-4800 8ch — effective ~150 ns latency, 38.4 GB/s/ch
  CXL   : 271 ns load-to-use, 22 GB/s   (Type-2 device link)
  SSD   : 45 µs random read, 1.2M IOPS (4 KiB granularity)

Accounting is per query batch: every pipeline stage records (tier, bytes,
accesses); ``QueryCost.total_seconds`` folds them with the tier model,
assuming accesses within a stage pipeline/overlap up to the tier's queue
parallelism (SSD QD, CXL banks), which is how the paper's accelerator and
the baseline's io_uring path both behave.

Billing-key convention
----------------------
Ledger keys are ``"stage:tier"`` with the tier always last (split with
``key.rsplit(":", 1)``); ``record(stage, tier, ...)`` builds them, nothing
else should.  The stage names in use:

  ``front:hbm``    device-side coarse stage (PQ scan / graph walk)
  ``handoff:cxl``  candidate ids+d0 crossing from device to far memory
  ``refine:cxl``   TRQ residual levels streamed from CXL (warm lists)
  ``delta:cxl``    streaming-index delta-page share of refine traffic
  ``hot:hbm``      full-precision rows of HBM-resident hot lists (tiered
                   layout: exact scoring, refinement skipped)
  ``cold:ssd``     residual levels of SSD-demoted cold lists (tiered
                   layout: level-0 and deeper levels at SSD rates)
  ``rerank:ssd``   exact full-vector fetches for final rerank

Consumers should not string-parse keys — use ``QueryCost.by_tier()`` for
per-tier totals and ``breakdown()`` for per-tier seconds.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from enum import Enum


class Tier(str, Enum):
    DRAM = "dram"
    CXL = "cxl"
    SSD = "ssd"
    HBM = "hbm"        # device-side (GPU/TPU front stage)


@dataclass(frozen=True)
class TierSpec:
    latency_s: float         # per-access load-to-use latency
    bandwidth_Bps: float     # sustained streaming bandwidth
    parallelism: float       # concurrent in-flight accesses (QD / banks)
    min_grain_B: int = 64    # minimum transfer granularity

    def seconds(self, accesses: int, nbytes: int) -> float:
        """Modeled time this tier spends serving ``accesses`` transfers
        totalling ``nbytes`` under the max(lat, bw) overlap model (see
        ``QueryCost.tier_seconds``).  Used both for ledger folding and
        for per-level span attribution in the observability layer."""
        lat = accesses * self.latency_s / self.parallelism
        return max(lat, nbytes / self.bandwidth_Bps)


TABLE_I = {
    Tier.DRAM: TierSpec(latency_s=150e-9, bandwidth_Bps=8 * 38.4e9,
                        parallelism=64, min_grain_B=64),
    Tier.CXL: TierSpec(latency_s=271e-9, bandwidth_Bps=22e9,
                       parallelism=32, min_grain_B=64),
    Tier.SSD: TierSpec(latency_s=45e-6, bandwidth_Bps=1_200_000 * 4096,
                       parallelism=256, min_grain_B=4096),
    Tier.HBM: TierSpec(latency_s=120e-9, bandwidth_Bps=600e9,
                       parallelism=128, min_grain_B=32),
}


@dataclass
class Traffic:
    """Accumulated traffic for one stage/tier."""

    accesses: int = 0
    bytes: int = 0

    def add(self, accesses: int, bytes_each: int, grain: int = 1) -> None:
        self.accesses += int(accesses)
        self.bytes += int(accesses) * max(int(bytes_each), grain)


@dataclass
class QueryCost:
    """Traffic ledger for a (batch of) queries against the tier model.

    ``parallel_s`` is set by ``merge_parallel`` when concurrent shard lanes
    have been folded in: per-tier times become explicit (the slowest lane)
    instead of being derived from the pooled traffic, which would read as
    if the lanes had run back-to-back.
    """

    model: dict[Tier, TierSpec] = field(default_factory=lambda: dict(TABLE_I))
    ledger: dict[str, Traffic] = field(default_factory=dict)
    compute_s: float = 0.0
    parallel_s: dict[str, float] = field(default_factory=dict)

    def record(self, stage: str, tier: Tier, accesses: int, bytes_each: int
               ) -> None:
        key = f"{stage}:{tier.value}"
        t = self.ledger.setdefault(key, Traffic())
        if self.parallel_s:
            # frozen ledger (post merge_parallel): keep time consistent by
            # adding this record's incremental key time to the tier's
            # frozen value — per-tier time is additive over keys.
            before = self._key_seconds(tier, t)
            t.add(accesses, bytes_each, self.model[tier].min_grain_B)
            self.parallel_s[tier.value] += self._key_seconds(tier, t) - before
        else:
            t.add(accesses, bytes_each, self.model[tier].min_grain_B)

    def _key_seconds(self, tier: Tier, t: "Traffic") -> float:
        """Time one stage key's traffic occupies a tier (see tier_seconds)."""
        return self.model[tier].seconds(t.accesses, t.bytes)

    def add_compute(self, seconds: float) -> None:
        self.compute_s += seconds

    def tier_seconds(self, tier: Tier) -> float:
        """Time a tier spends serving this ledger's traffic.

        Overlap model: within a stage, accesses pipeline up to the tier's
        queue parallelism (SSD QD, CXL banks), so the latency term amortizes
        to ``accesses · latency / parallelism`` while data streams at the
        sustained bandwidth.  Latency and transfer fully overlap — the stage
        is bound by whichever is larger, hence ``max(lat, bw)`` (not the
        sum): a deep-queued tier hides per-access latency behind streaming,
        and a latency-bound tier hides the (smaller) transfer time inside
        its access pipeline.
        """
        if tier.value in self.parallel_s:
            return self.parallel_s[tier.value]
        total = 0.0
        for key, t in self.ledger.items():
            # keys are "stage:tier" — parse the tier component instead of
            # suffix-matching, so a stage name can never alias a tier (e.g.
            # a stage literally called "overssd" must not match Tier.SSD).
            if key.rsplit(":", 1)[-1] != tier.value:
                continue
            total += self._key_seconds(tier, t)
        return total

    def total_seconds(self) -> float:
        """Stages on different tiers overlap poorly across the refinement
        dependency chain; we take the sum of per-tier times + compute (the
        paper's pipeline is serialized coarse → refine → SSD rerank)."""
        return sum(self.tier_seconds(t) for t in Tier) + self.compute_s

    def breakdown(self) -> dict[str, float]:
        out = {t.value: self.tier_seconds(t) for t in Tier}
        out["compute"] = self.compute_s
        return out

    def by_tier(self) -> dict[Tier, Traffic]:
        """Pooled traffic per tier (every tier present, zero if untouched),
        so consumers aggregate by tier without parsing ledger keys."""
        out = {t: Traffic() for t in Tier}
        for key, t in self.ledger.items():
            tier = Tier(key.rsplit(":", 1)[-1])
            out[tier].accesses += t.accesses
            out[tier].bytes += t.bytes
        return out

    def merge(self, other: "QueryCost") -> "QueryCost":
        """Fold another ledger's traffic + compute into this one (in place),
        with SERIAL semantics: the other batch ran after this one, so times
        add — as do traffic and compute.

        Used by serving to keep a running total across request batches.  If
        either side has been parallel-folded (``parallel_s`` set), per-tier
        times are re-frozen as the sum of both sides' times, since the
        pooled traffic can no longer reproduce them.
        """
        if self.parallel_s or other.parallel_s:
            frozen = {t.value: self.tier_seconds(t) + other.tier_seconds(t)
                      for t in Tier}
        else:
            frozen = None
        for key, t in other.ledger.items():
            mine = self.ledger.setdefault(key, Traffic())
            mine.accesses += t.accesses
            mine.bytes += t.bytes
        self.compute_s += other.compute_s
        if frozen is not None:
            self.parallel_s = frozen
        return self

    def merge_parallel(self, other: "QueryCost") -> "QueryCost":
        """Fold a CONCURRENT lane's ledger into this one (in place).

        Overlap model (documented like ``tier_seconds``'s ``max(lat, bw)``):
        parallel shards run at the same time on disjoint channel slices, so
        traffic (accesses + bytes) SUMS — the capacity-planning view: every
        lane really moved its bytes — while per-tier time and compute take
        the MAX across lanes: the batch completes when the slowest lane
        does.  Chaining ``a.merge_parallel(b).merge_parallel(c)`` folds any
        number of lanes (max is associative).

        After this call per-tier times are frozen in ``parallel_s``; later
        ``record``s (serial work after the parallel phase) and ``merge``s
        extend the frozen times additively.
        """
        frozen = {t.value: max(self.tier_seconds(t), other.tier_seconds(t))
                  for t in Tier}
        for key, t in other.ledger.items():
            mine = self.ledger.setdefault(key, Traffic())
            mine.accesses += t.accesses
            mine.bytes += t.bytes
        self.compute_s = max(self.compute_s, other.compute_s)
        self.parallel_s = frozen
        return self

    def copy(self) -> "QueryCost":
        c = QueryCost(model=dict(self.model))
        c.ledger = {k: dataclasses.replace(v) for k, v in self.ledger.items()}
        c.compute_s = self.compute_s
        c.parallel_s = dict(self.parallel_s)
        return c
