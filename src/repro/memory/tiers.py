"""Tiered-memory cost model (paper Table I) + traffic accounting.

The container has no CXL device or SSD on the hot path, so end-to-end
throughput claims (Fig. 6) are reproduced through this calibrated analytical
model, exactly the constants the paper simulates with (Ramulator DDR5 +
Samsung 990 Pro + Marvell Structera):

  DRAM  : DDR5-4800 8ch — effective ~150 ns latency, 38.4 GB/s/ch
  CXL   : 271 ns load-to-use, 22 GB/s   (Type-2 device link)
  SSD   : 45 µs random read, 1.2M IOPS (4 KiB granularity)

Accounting is per query batch: every pipeline stage records (tier, bytes,
accesses); ``QueryCost.total_seconds`` folds them with the tier model,
assuming accesses within a stage pipeline/overlap up to the tier's queue
parallelism (SSD QD, CXL banks), which is how the paper's accelerator and
the baseline's io_uring path both behave.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from enum import Enum


class Tier(str, Enum):
    DRAM = "dram"
    CXL = "cxl"
    SSD = "ssd"
    HBM = "hbm"        # device-side (GPU/TPU front stage)


@dataclass(frozen=True)
class TierSpec:
    latency_s: float         # per-access load-to-use latency
    bandwidth_Bps: float     # sustained streaming bandwidth
    parallelism: float       # concurrent in-flight accesses (QD / banks)
    min_grain_B: int = 64    # minimum transfer granularity


TABLE_I = {
    Tier.DRAM: TierSpec(latency_s=150e-9, bandwidth_Bps=8 * 38.4e9,
                        parallelism=64, min_grain_B=64),
    Tier.CXL: TierSpec(latency_s=271e-9, bandwidth_Bps=22e9,
                       parallelism=32, min_grain_B=64),
    Tier.SSD: TierSpec(latency_s=45e-6, bandwidth_Bps=1_200_000 * 4096,
                       parallelism=256, min_grain_B=4096),
    Tier.HBM: TierSpec(latency_s=120e-9, bandwidth_Bps=600e9,
                       parallelism=128, min_grain_B=32),
}


@dataclass
class Traffic:
    """Accumulated traffic for one stage/tier."""

    accesses: int = 0
    bytes: int = 0

    def add(self, accesses: int, bytes_each: int, grain: int = 1) -> None:
        self.accesses += int(accesses)
        self.bytes += int(accesses) * max(int(bytes_each), grain)


@dataclass
class QueryCost:
    """Traffic ledger for a (batch of) queries against the tier model."""

    model: dict[Tier, TierSpec] = field(default_factory=lambda: dict(TABLE_I))
    ledger: dict[str, Traffic] = field(default_factory=dict)
    compute_s: float = 0.0

    def record(self, stage: str, tier: Tier, accesses: int, bytes_each: int
               ) -> None:
        key = f"{stage}:{tier.value}"
        t = self.ledger.setdefault(key, Traffic())
        t.add(accesses, bytes_each, self.model[tier].min_grain_B)

    def add_compute(self, seconds: float) -> None:
        self.compute_s += seconds

    def tier_seconds(self, tier: Tier) -> float:
        """Time a tier spends serving this ledger's traffic.

        Overlap model: within a stage, accesses pipeline up to the tier's
        queue parallelism (SSD QD, CXL banks), so the latency term amortizes
        to ``accesses · latency / parallelism`` while data streams at the
        sustained bandwidth.  Latency and transfer fully overlap — the stage
        is bound by whichever is larger, hence ``max(lat, bw)`` (not the
        sum): a deep-queued tier hides per-access latency behind streaming,
        and a latency-bound tier hides the (smaller) transfer time inside
        its access pipeline.
        """
        spec = self.model[tier]
        total = 0.0
        for key, t in self.ledger.items():
            if not key.endswith(tier.value):
                continue
            lat = t.accesses * spec.latency_s / spec.parallelism
            bw = t.bytes / spec.bandwidth_Bps
            total += max(lat, bw)
        return total

    def total_seconds(self) -> float:
        """Stages on different tiers overlap poorly across the refinement
        dependency chain; we take the sum of per-tier times + compute (the
        paper's pipeline is serialized coarse → refine → SSD rerank)."""
        return sum(self.tier_seconds(t) for t in Tier) + self.compute_s

    def breakdown(self) -> dict[str, float]:
        out = {t.value: self.tier_seconds(t) for t in Tier}
        out["compute"] = self.compute_s
        return out

    def merge(self, other: "QueryCost") -> "QueryCost":
        """Fold another ledger's traffic + compute into this one (in place).

        Used by serving to keep a running total across request batches.
        """
        for key, t in other.ledger.items():
            mine = self.ledger.setdefault(key, Traffic())
            mine.accesses += t.accesses
            mine.bytes += t.bytes
        self.compute_s += other.compute_s
        return self

    def copy(self) -> "QueryCost":
        c = QueryCost(model=dict(self.model))
        c.ledger = {k: dataclasses.replace(v) for k, v in self.ledger.items()}
        c.compute_s = self.compute_s
        return c
