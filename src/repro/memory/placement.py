"""Heat-driven hot/warm/cold placement for IVF lists (paper §V turned
adaptive).

FaTRQ's static assignment pins every list to the same tier trio: PQ codes
in device HBM, TRQ residuals in CXL, full vectors on SSD.  Real workloads
are skewed — a few hot lists absorb most probes — so this module derives a
per-list placement from observed traffic:

  hot   lists keep full-precision rows resident in HBM; the executor scores
        them exactly and skips progressive refinement entirely (billed to
        ``hot:hbm``),
  warm  lists stay on today's fused TRQ path (residuals in CXL),
  cold  lists demote to SSD-resident residuals: their level-0 stream and
        every deeper level are billed at SSD rates (``cold:ssd``).

Everything here is plain numpy and deterministic: the heat tracker is an
EMA over the per-list access counters the executor already folds, and the
policy is a stable sort against occupancy budgets.  The jax-facing side
(``TieredIndex`` in ``anns/tiered.py``) owns device arrays, generations and
migration; this module owns the math.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# Per-row tier codes, stored in the ``TieredIndex`` placement array and
# gathered per candidate on device.  WARM is the identity placement: an
# all-WARM tiered index is bit-identical to the static layout.
TIER_HOT = 0
TIER_WARM = 1
TIER_COLD = 2

TIER_NAMES = ("hot", "warm", "cold")


@dataclass(frozen=True)
class TieredConfig:
    """Placement policy knobs.

    ``hot_rows_frac`` / ``cold_rows_frac`` are occupancy budgets as a
    fraction of total rows: the policy promotes the hottest lists into HBM
    until the hot budget is full, and demotes the coldest lists to SSD up
    to the cold budget.  ``decay`` is the EMA coefficient (heat carried
    over per observation batch); ``min_observations`` gates rebalancing so
    one query can't thrash placement.  ``enabled=False`` forces all-WARM,
    the static-equivalent placement.
    """

    decay: float = 0.8
    hot_rows_frac: float = 0.1
    cold_rows_frac: float = 0.0
    min_observations: int = 1
    enabled: bool = True

    def __post_init__(self) -> None:
        if not (0.0 <= self.decay < 1.0):
            raise ValueError(f"decay must be in [0, 1), got {self.decay}")
        if self.hot_rows_frac < 0 or self.cold_rows_frac < 0:
            raise ValueError("tier occupancy fractions must be >= 0")
        if self.hot_rows_frac + self.cold_rows_frac > 1.0 + 1e-9:
            raise ValueError("hot_rows_frac + cold_rows_frac must be <= 1")


class HeatTracker:
    """EMA-decayed per-list access heat.

    ``observe`` folds one batch's per-list candidate counts (the
    ``list_heat`` counter the executor emits);  given the same query trace
    the heat vector is bit-for-bit reproducible — no wall clock anywhere.
    """

    def __init__(self, nlist: int, decay: float = 0.8) -> None:
        self.decay = float(decay)
        self.heat = np.zeros(int(nlist), dtype=np.float64)
        self.observations = 0

    def observe(self, counts: np.ndarray) -> None:
        counts = np.asarray(counts, dtype=np.float64)
        if counts.shape != self.heat.shape:
            raise ValueError(
                f"heat counts shape {counts.shape} != ({self.heat.shape[0]},)")
        self.heat = self.decay * self.heat + (1.0 - self.decay) * counts
        self.observations += 1

    def reset(self) -> None:
        self.heat[:] = 0.0
        self.observations = 0


def plan_placement(heat: np.ndarray, list_rows: np.ndarray,
                   cfg: TieredConfig) -> np.ndarray:
    """Classify every list hot/warm/cold against the occupancy budgets.

    Deterministic: lists are ranked by (heat desc, list id asc).  The
    hottest lists with nonzero heat are promoted while their rows fit the
    hot budget; the coldest non-hot lists are demoted while they fit the
    cold budget.  Returns an int8 ``(nlist,)`` tier-code array.
    """
    heat = np.asarray(heat, dtype=np.float64)
    list_rows = np.asarray(list_rows, dtype=np.int64)
    nlist = heat.shape[0]
    tiers = np.full(nlist, TIER_WARM, dtype=np.int8)
    if not cfg.enabled or nlist == 0:
        return tiers
    n_rows = int(list_rows.sum())
    order = np.lexsort((np.arange(nlist), -heat))  # heat desc, id asc

    hot_budget = int(cfg.hot_rows_frac * n_rows)
    used = 0
    for li in order:
        if heat[li] <= 0.0:
            break  # remaining lists are unobserved — never promote those
        rows = int(list_rows[li])
        if used + rows > hot_budget:
            continue
        tiers[li] = TIER_HOT
        used += rows

    cold_budget = int(cfg.cold_rows_frac * n_rows)
    used = 0
    for li in order[::-1]:  # heat asc, id desc
        if tiers[li] == TIER_HOT:
            continue
        rows = int(list_rows[li])
        if used + rows > cold_budget:
            continue
        tiers[li] = TIER_COLD
        used += rows
    return tiers


def plan_migration(old: np.ndarray, new: np.ndarray,
                   list_rows: np.ndarray) -> dict[tuple[str, str], int]:
    """Rows moved per (from_tier, to_tier) transition — the migration
    plan ``rebalance_tiers`` executes and the obs layer counts."""
    old = np.asarray(old)
    new = np.asarray(new)
    list_rows = np.asarray(list_rows, dtype=np.int64)
    moves: dict[tuple[str, str], int] = {}
    changed = np.nonzero(old != new)[0]
    for li in changed:
        key = (TIER_NAMES[int(old[li])], TIER_NAMES[int(new[li])])
        moves[key] = moves.get(key, 0) + int(list_rows[li])
    return moves


def occupancy(tiers: np.ndarray, list_rows: np.ndarray
              ) -> dict[str, tuple[int, int]]:
    """Per-tier (lists, rows) occupancy, for gauges and reports."""
    tiers = np.asarray(tiers)
    list_rows = np.asarray(list_rows, dtype=np.int64)
    out = {}
    for code, name in enumerate(TIER_NAMES):
        m = tiers == code
        out[name] = (int(m.sum()), int(list_rows[m].sum()))
    return out
