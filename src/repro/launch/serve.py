"""Serving launcher: batched decode with optional FaTRQ-RAG retrieval.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --batch 4 --steps 16 [--rag]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import build_model
from repro.serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--rag", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = Engine(api, params, batch=args.batch, max_len=args.max_len)
    if cfg.enc_dec:
        frames = jax.random.normal(jax.random.PRNGKey(1),
                                   (args.batch, cfg.enc_frames,
                                    cfg.d_model))
        engine.prefill({"frames": frames})

    seed = jnp.zeros((args.batch, 1), jnp.int32)
    t0 = time.time()
    out = engine.decode(seed, args.steps)
    dt = time.time() - t0
    print(f"decoded {args.batch}×{args.steps} tokens in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s incl. compile)")

    if args.rag:
        from repro.anns import PipelineConfig, build
        from repro.data import make_dataset
        from repro.serving import rag_answer
        ds = make_dataset(jax.random.PRNGKey(2), n=8_000, d=cfg.d_model,
                          n_queries=4)
        index = build(jax.random.PRNGKey(3), ds.x,
                      PipelineConfig(dim=cfg.d_model, pq_m=16, pq_k=64,
                                     nlist=32, nprobe=8, final_k=5,
                                     refine_budget=20))

        def embed_fn(tokens):
            e = params["embed"][tokens].mean(axis=1)
            return e / jnp.linalg.norm(e, axis=-1, keepdims=True)

        prompts = jax.random.randint(jax.random.PRNGKey(4),
                                     (args.batch, 8), 0, cfg.vocab)
        res = rag_answer(engine, index, embed_fn, prompts)
        print(f"RAG: retrieved {res.ids.shape[1]} docs/request; "
              f"retrieval {res.cost.total_seconds() / args.batch * 1e6:.0f}"
              f"us/query (modeled); degraded={res.degraded}")


if __name__ == "__main__":
    main()
