"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is pure
data parallelism across the DCN/ICI-superlink boundary — gradients reduce
hierarchically (model → data → pod), which XLA emits as a two-stage
all-reduce.

Functions, not module constants: importing this module must never touch
jax device state (dryrun.py sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for smoke tests / examples on this container."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_search_mesh(n: int | None = None):
    """1-D ``("search",)`` mesh for the sharded ANNS datapath.

    ``n`` shards over the first n devices (default: all available).  On a
    CPU container, fake devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before the
    first jax call).
    """
    avail = len(jax.devices())
    n = avail if n is None else n
    if n > avail:
        raise ValueError(
            f"make_search_mesh({n}) needs {n} devices but only {avail} are "
            f"visible; set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} before the first jax call (host-platform meshes)")
    return jax.make_mesh((n,), ("search",))


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes used for batch/data parallelism (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
