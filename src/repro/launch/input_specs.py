"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell —
weak-type-correct, shardable, zero device allocation.

For [vlm]/[audio] archs the modality frontend is a stub: input_specs
provides precomputed patch/frame embeddings per the assignment.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.model_zoo import ModelApi


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                      dtype=jnp.bfloat16) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s), jnp.int32),
             "labels": sds((b, s), jnp.int32)}
    if cfg.enc_dec:
        batch["frames"] = sds((b, cfg.enc_frames, cfg.d_model), dtype)
    if cfg.family == "vlm":
        # M-RoPE position triples (t, h, w) for mixed image-text batches
        batch["positions"] = sds((b, 3, s), jnp.int32)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                        dtype=jnp.bfloat16) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s), jnp.int32)}
    if cfg.enc_dec:
        batch["frames"] = sds((b, cfg.enc_frames, cfg.d_model), dtype)
    if cfg.family == "vlm":
        batch["positions"] = sds((b, 3, s), jnp.int32)
        # dynamic-resolution patch embeddings (frontend stub): the prompt is
        # image patches + text, already embedded
        batch["embeds"] = sds((b, s, cfg.d_model), dtype)
        del batch["positions"]  # embeds path uses default positions
    return batch


def params_structs(api: ModelApi, dtype=jnp.bfloat16):
    """Abstract param tree via eval_shape — no allocation."""
    key = sds((2,), jnp.uint32)
    return jax.eval_shape(lambda k: api.init(k, dtype), key)


def cache_structs(api: ModelApi, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: api.init_cache(None, batch, max_len, dtype))


def decode_token_specs(shape: ShapeConfig) -> jax.ShapeDtypeStruct:
    return sds((shape.global_batch, 1), jnp.int32)
