"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 100 --batch 8 --seq 128 [--reduced]

On this container it runs the reduced config on the host mesh; on a real
fleet the same entry point builds the production mesh and the pjit train
step from launch/steps.py (--production flag lowers through the sharded
path; requires the device count).
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS, SHAPES
from repro.models import build_model
from repro.train.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--full", action="store_true",
                    help="full (production) config instead of reduced")
    args = ap.parse_args()

    cfg = ARCHS[args.arch] if args.full else ARCHS[args.arch].reduced()
    api = build_model(cfg)
    print(f"training {cfg.name} ({'full' if args.full else 'reduced'}) "
          f"≈{cfg.params_count() / 1e6:.0f}M params on "
          f"{len(jax.devices())} device(s)")
    tc = TrainConfig(steps=args.steps, batch=args.batch, seq_len=args.seq,
                     lr=args.lr, ckpt_every=max(args.steps // 4, 1),
                     ckpt_dir=args.ckpt_dir)
    state = train(api, tc, resume=True)
    if state.losses:
        print(f"done: step={state.step} loss {state.losses[0]:.3f} → "
              f"{state.losses[-1]:.3f} (stragglers={state.stragglers}, "
              f"skipped={state.skipped})")
    else:
        print(f"done: step={state.step} (resumed past --steps; no new "
              f"steps run)")


if __name__ == "__main__":
    main()
