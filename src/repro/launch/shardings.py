"""Parameter / activation / cache PartitionSpecs for every model family.

Strategy (TPU v5e, mesh (pod?, data, model)):
  * 2-D sharding of every large weight: TP along `model` on the "wide" dim
    (heads / d_ff / vocab), FSDP along `(pod, data)` on the other dim —
    optimizer state inherits it, so a 72B model + Adam fits 256 chips.
  * MoE experts: expert-parallel along `model` when n_experts divides the
    axis, otherwise TP inside each expert (mixtral's 8 experts on a
    16-way axis).
  * Every rule checks divisibility and degrades to replication — vocab
    51865 (whisper) simply cannot shard 16 ways.
  * Caches: batch → data axes, KV-heads → model; long-context batch=1
    falls back to sequence sharding (see cache_specs).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes, mesh_axis_sizes

# weight-name → (tp_dim, fsdp_dim); tp_dim = the dim sharded along `model`
_TP_LAST = ("wq", "wk", "wv", "wg", "wu", "up", "in_proj", "wi", "w_gates",
            "lm_head", "w_if")
_TP_FIRST = ("wo", "wd", "down", "out_proj")
_REPLICATE = ("ln", "ln1", "ln2", "lnx", "final_norm", "enc_norm",
              "gate_norm", "out_norm", "A_log", "dt_bias", "conv",
              "router", "r_gates", "dec_pos", "enc_pos")


def _divides(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _axis_size(mesh, name) -> int:
    sizes = mesh_axis_sizes(mesh)
    if isinstance(name, tuple):
        out = 1
        for a in name:
            out *= sizes.get(a, 1)
        return out
    return sizes.get(name, 1)


# containers whose leading dim(s) are LAYER-STACK dims (consumed by the
# layer scan) — specs must skip them or per-iteration weight gathers ensue
_STACK1 = ("blocks", "enc_blocks", "dec_blocks", "tail", "slstm_blocks")
_STACK2 = ("mlstm_blocks", "groups")


def _stack_dims(parts: tuple[str, ...]) -> int:
    if any(p in _STACK2 for p in parts):
        return 2
    if any(p in _STACK1 for p in parts):
        return 1
    return 0


def param_spec(mesh, name: str, shape: tuple[int, ...], *, fsdp: bool = True,
               mode: str = "2d") -> P:
    """PartitionSpec for one weight by path/shape — stack-aware: leading
    layer-stack dims are never sharded (scan slices them per iteration).

    mode="2d": TP along `model` + FSDP along data axes (default).
    mode="fsdp": pure FSDP over ALL mesh axes, no tensor parallelism —
    the right scheme for models whose per-layer weights fit one chip
    (eliminates TP/SP activation collectives; see EXPERIMENTS §Perf)."""
    model = "model"
    msize = _axis_size(mesh, model)
    dp = dp_axes(mesh)
    if mode == "fsdp":
        dp = tuple(mesh.axis_names)          # fold model into FSDP
        msize = 10**9                        # nothing divides → no TP
        fsdp = True
    dsize = _axis_size(mesh, dp)
    parts = tuple(name.split("/"))
    base = parts[-1]
    lead = _stack_dims(parts)
    core = shape[lead:]
    head = [None] * lead

    def maybe_fsdp(dim_size):
        return dp if (fsdp and _divides(dim_size, dsize)) else None

    if base in _REPLICATE or len(core) == 0:
        return P(*([None] * len(shape)))
    if base in ("bq", "bk", "bv"):
        tp = model if _divides(core[0], msize) else None
        return P(*head, tp)
    if base == "embed":
        # vocab-sharded along model, d_model FSDP along data; if vocab
        # doesn't divide, shard d_model instead (never replicate a table)
        if _divides(shape[0], msize):
            return P(model, maybe_fsdp(shape[1]))
        if _divides(shape[1], msize):
            return P(None, model)
        return P(None, maybe_fsdp(shape[1]))
    if "moe" in parts and len(core) == 3 and base in ("wg", "wu", "wd"):
        # MoE experts (E, D, F) / (E, F, D)
        e = core[0]
        if _divides(e, msize):
            return P(*head, model, maybe_fsdp(core[1]), None)  # expert-par
        tp_dim = 2 if base in ("wg", "wu") else 1
        spec = [None, None, None]
        if _divides(core[tp_dim], msize):
            spec[tp_dim] = model
        other = 2 if tp_dim == 1 else 1
        spec[other] = maybe_fsdp(core[other])
        return P(*head, *spec)
    if base in _TP_LAST and len(core) >= 2:
        tp = model if _divides(core[-1], msize) else None
        return P(*head, maybe_fsdp(core[0]),
                 *([None] * (len(core) - 2)), tp)
    if base in _TP_FIRST and len(core) >= 2:
        tp = model if _divides(core[0], msize) else None
        return P(*head, tp, *([None] * (len(core) - 2)),
                 maybe_fsdp(core[-1]))
    return P(*([None] * len(shape)))


def param_specs(mesh, params, *, fsdp: bool = True, mode: str = "2d"):
    """Specs pytree matching `params` (works on ShapeDtypeStruct trees)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        # normalize "['blocks']['attn']['wq']" → "blocks/attn/wq"
        name = name.replace("']['", "/").strip("[']")
        specs.append(param_spec(mesh, name, leaf.shape, fsdp=fsdp,
                                mode=mode))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(mesh, batch, *, mode: str = "2d") -> dict:
    """tokens/labels (B, S) → batch over (pod, data) [all axes in fsdp
    mode]; embeds/frames too."""
    dp = dp_axes(mesh) if mode == "2d" else tuple(mesh.axis_names)
    dsize = _axis_size(mesh, dp)

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        b = leaf.shape[0]
        first = dp if _divides(b, dsize) else None
        return P(first, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(spec, batch)


def cache_spec_for(mesh, shape: tuple[int, ...], kind: str) -> P:
    """KV caches (L, B, S, KV, hd) and SSM states — batch→data, heads→model,
    falling back to sequence→data for batch=1 long-context."""
    dp = dp_axes(mesh)
    dsize = _axis_size(mesh, dp)
    msize = _axis_size(mesh, "model")
    if kind == "kv":                        # (L|G, B, S, KV, hd)
        _, b, s, kv, _ = shape
        spec = [None, None, None, None, None]
        if _divides(b, dsize):
            spec[1] = dp
        elif _divides(s, dsize):
            spec[2] = dp                    # batch=1 → shard sequence
        if _divides(kv, msize):
            spec[3] = "model"
        elif spec[2] is None and _divides(s, msize):
            spec[2] = "model"
        return P(*spec)
    # generic state: try batch dim then the largest trailing dim
    spec = [None] * len(shape)
    for i, n in enumerate(shape):
        if spec.count(dp) == 0 and _divides(n, dsize) and n >= dsize \
                and i >= len(shape) - 4:
            spec[i] = dp
            break
    for i in range(len(shape) - 1, -1, -1):
        if spec[i] is None and _divides(shape[i], msize) \
                and shape[i] >= msize:
            spec[i] = "model"
            break
    return P(*spec)


def cache_specs(mesh, cache):
    def spec(path, leaf):
        name = jax.tree_util.keystr(path)
        if leaf.ndim == 0:
            return P()
        if any(k in name for k in ("'k'", "'v'", "attn_k", "attn_v", "xk",
                                   "xv")) and leaf.ndim == 5:
            return cache_spec_for(mesh, leaf.shape, "kv")
        return cache_spec_for(mesh, leaf.shape, "state")

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def named(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
