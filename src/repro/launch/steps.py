"""pjit train / prefill / decode step builders with full shardings.

Each make_* returns (step_fn, arg_structs, in_shardings, out_shardings)
ready for ``jax.jit(step_fn, ...).lower(*arg_structs)`` — the dry-run path —
or for real execution with concrete arrays of the same shardings.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch import input_specs as ispec
from repro.launch import shardings as sh
from repro.models.model_zoo import ModelApi, build_model, loss_fn
from repro.train import optimizer


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _activate_constraints(mesh, *, seq_parallel: bool = False,
                          flash_decode: bool = False):
    """Enable MaxText-style activation sharding constraints in the model
    code for subsequent traces (see models/layers.py)."""
    from repro.launch.mesh import dp_axes, mesh_axis_sizes
    from repro.models import layers as mlayers
    sizes = mesh_axis_sizes(mesh)
    dp = dp_axes(mesh)
    dsize = 1
    for a in dp:
        dsize *= sizes[a]
    mlayers.set_mesh_axes(dp, dsize, sizes.get("model", 1),
                          seq_parallel=seq_parallel, mesh=mesh,
                          flash_decode=flash_decode)


def choose_microbatches(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                        seq_parallel: bool, budget_bytes: float = 4e9
                        ) -> int:
    """Gradient-accumulation factor: smallest divisor of the per-device
    batch keeping the layer-carry residual stack under `budget_bytes`."""
    dp = _dp(mesh)
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    b_loc = max(shape.global_batch // dp, 1)
    tokens = b_loc * shape.seq_len
    if seq_parallel:
        tokens = tokens // msize
    layers = cfg.n_layers + (cfg.n_enc_layers or 0)
    resid = layers * tokens * cfg.d_model * 6        # f32 + bf16 copies
    micro = 1
    while resid / micro > budget_bytes and micro < b_loc:
        micro *= 2
    while shape.global_batch % (micro * dp) and micro > 1:
        micro //= 2
    return micro


def make_train_step(api: ModelApi, mesh, shape: ShapeConfig, *,
                    dtype=jnp.bfloat16, lr: float = 3e-4,
                    seq_parallel: bool = True,
                    num_micro: int | None = None,
                    sharding_mode: str = "2d"):
    """loss + grad + AdamW update; FSDP×TP sharded, sequence-parallel
    activations, gradient-accumulation microbatching.

    sharding_mode="fsdp": pure FSDP over all axes (no TP/SP) — optimal for
    models whose layers fit one chip (see shardings.param_spec)."""
    cfg = api.cfg
    if sharding_mode == "fsdp":
        seq_parallel = False
        from repro.launch.mesh import mesh_axis_sizes
        from repro.models import layers as mlayers
        all_axes = tuple(mesh.axis_names)
        total = mesh.devices.size
        mlayers.set_mesh_axes(all_axes, total, 1, mesh=mesh)
    else:
        _activate_constraints(mesh, seq_parallel=seq_parallel)
    if num_micro is None:
        num_micro = choose_microbatches(cfg, shape, mesh,
                                        seq_parallel=seq_parallel)

    def train_step(params, opt_state, batch):
        if num_micro == 1:
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(api, p, batch))(params)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape(num_micro, a.shape[0] // num_micro,
                                    *a.shape[1:]), batch)

            def mb(acc, mbatch):
                l, g = jax.value_and_grad(
                    lambda p: loss_fn(api, p, mbatch))(params)
                return jax.tree.map(jnp.add, acc, g), l

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(mb, zeros, micro)
            grads = jax.tree.map(lambda g: g / num_micro, grads)
            loss = jnp.mean(losses)
        new_params, new_opt = optimizer.update(grads, opt_state, params,
                                               lr=lr)
        return loss, new_params, new_opt

    params_s = ispec.params_structs(api, dtype)
    opt_s = jax.eval_shape(optimizer.init, params_s)
    batch_s = ispec.train_batch_specs(cfg, shape, dtype)

    p_spec = sh.param_specs(mesh, params_s, fsdp=True, mode=sharding_mode)
    opt_spec = optimizer.AdamWState(step=P(), mu=p_spec, nu=p_spec)
    b_spec = sh.batch_specs(mesh, batch_s, mode=sharding_mode)

    in_sh = (_named(mesh, p_spec), _named(mesh, opt_spec),
             _named(mesh, b_spec))
    out_sh = (_named(mesh, P()), in_sh[0], in_sh[1])
    meta = {"num_micro": num_micro, "seq_parallel": seq_parallel,
            "cost_repeat": num_micro, "sharding_mode": sharding_mode}
    return train_step, (params_s, opt_s, batch_s), in_sh, out_sh, meta


def make_prefill_step(api: ModelApi, mesh, shape: ShapeConfig, *,
                      dtype=jnp.bfloat16):
    """Prompt pass → last-position logits (inference prefill)."""
    cfg = api.cfg
    _activate_constraints(mesh)

    def prefill_step(params, batch):
        logits, _ = api.forward(params, batch, last_only=True, remat=False)
        return logits

    params_s = ispec.params_structs(api, dtype)
    batch_s = ispec.prefill_batch_specs(cfg, shape, dtype)
    p_spec = sh.param_specs(mesh, params_s, fsdp=False)   # weights TP-only
    b_spec = sh.batch_specs(mesh, batch_s)
    in_sh = (_named(mesh, p_spec), _named(mesh, b_spec))
    out_sh = _named(mesh, P(sh.dp_axes(mesh) if
                            shape.global_batch % _dp(mesh) == 0 else None))
    return prefill_step, (params_s, batch_s), in_sh, out_sh, {
        "cost_repeat": 1}


def _dp(mesh) -> int:
    out = 1
    for a, n in zip(mesh.axis_names, mesh.devices.shape):
        if a in ("pod", "data"):
            out *= n
    return out


def make_decode_step(api: ModelApi, mesh, shape: ShapeConfig, *,
                     dtype=jnp.bfloat16, flash_decode: bool | None = None):
    """One-token serve_step against a seq_len KV cache.

    flash_decode defaults ON exactly when the cache falls back to
    sequence sharding (KV heads don't divide the TP axis) — the case
    where plain attention makes XLA gather the cache."""
    cfg = api.cfg
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if flash_decode is None:
        flash_decode = cfg.n_kv_heads % msize != 0
    _activate_constraints(mesh, flash_decode=flash_decode)

    def serve_step(params, tokens, cache):
        logits, new_cache = api.decode_step(params, tokens, cache)
        return logits, new_cache

    params_s = ispec.params_structs(api, dtype)
    cache_s = ispec.cache_structs(api, shape.global_batch, shape.seq_len,
                                  dtype)
    tok_s = ispec.decode_token_specs(shape)

    p_spec = sh.param_specs(mesh, params_s, fsdp=False)
    c_spec = sh.cache_specs(mesh, cache_s)
    t_spec = sh.batch_specs(mesh, {"t": tok_s})["t"]
    in_sh = (_named(mesh, p_spec), _named(mesh, t_spec),
             _named(mesh, c_spec))
    out_sh = (_named(mesh, P(None)), in_sh[2])
    return serve_step, (params_s, tok_s, cache_s), in_sh, out_sh, {
        "cost_repeat": 1, "flash_decode": flash_decode}


def make_step(arch: ArchConfig, mesh, shape: ShapeConfig,
              dtype=jnp.bfloat16, **kwargs):
    """Dispatch on shape.kind; returns (fn, structs, in_sh, out_sh, meta).
    kwargs forward to the specific builder (perf-variant knobs)."""
    api = build_model(arch)
    if shape.kind == "train":
        return make_train_step(api, mesh, shape, dtype=dtype, **kwargs)
    if shape.kind == "prefill":
        return make_prefill_step(api, mesh, shape, dtype=dtype, **kwargs)
    return make_decode_step(api, mesh, shape, dtype=dtype, **kwargs)
