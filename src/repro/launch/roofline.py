"""Roofline-term extraction from a compiled (AOT) dry-run artifact.

    compute term    = HLO_FLOPs  / peak_FLOPs              (per chip)
    memory term     = HLO_bytes  / HBM_bw                  (per chip)
    collective term = collective_bytes / ICI link_bw       (per chip)

cost_analysis() runs on the SPMD-partitioned module, so FLOPs/bytes are
already per-device.  collective_bytes is not in cost_analysis — we parse
the partitioned HLO and sum the RESULT shapes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (the bytes a
device receives over ICI; all-reduce counted once per hop ≈ 2·(n−1)/n·size
simplified to 2× result size for ring execution).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result of an HLO op: "bf16[256,1024]{1,0}" or tuple "(f32[2], bf16[4,4])"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes inside shape_str."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int] = field(default_factory=dict)
    count_by_op: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective in the partitioned HLO."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start|-done)?\(", line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue    # counted at -start
        shape_str, op = m.group(1), m.group(2)
        b = shape_bytes(shape_str)
        if op == "all-reduce":
            b *= 2      # ring all-reduce ≈ reduce-scatter + all-gather
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + b
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                # per device
    hbm_bytes: float            # per device
    coll_bytes: float           # per device
    coll_detail: dict
    peak_memory_bytes: float
    model_flops: float          # 6·N·D (global)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips × HLO_FLOPs) — remat/redundancy waste."""
        total_hlo = self.flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs / (step_time × chips × peak) — the roofline score.
        Conservative: uses the HLO bytes-accessed memory term, which is a
        PRE-FUSION upper bound (every op's operands counted; on TPU, fusion
        keeps most intermediates in VMEM/VREGs)."""
        denom = self.step_time_s * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    @property
    def mfu_optimistic(self) -> float:
        """MFU with the fusion-optimistic memory floor: params + in/out
        arguments once per step (perfect fusion).  True MFU lies between
        `mfu` and this."""
        mem_floor = self.peak_memory_bytes / HBM_BW
        step = max(self.compute_s, min(self.memory_s, mem_floor),
                   self.collective_s)
        denom = step * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "coll_detail": self.coll_detail,
            "peak_memory_bytes": self.peak_memory_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck, "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio, "mfu": self.mfu,
            "mfu_optimistic": self.mfu_optimistic,
        }


def model_flops_for(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode: D = batch tokens."""
    n = cfg.active_params_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens      # forward only
    return 2.0 * n * shape.global_batch   # one token per sequence


def analyze(compiled, lowered_text: str, *, arch: str, shape, mesh_name: str,
            chips: int, cfg, cost_repeat: int = 1) -> RooflineReport:
    """cost_repeat: multiplier for costs sitting inside a microbatch loop
    (XLA counts a while body once; the optimizer epilogue outside the loop
    is overcounted by <1%, noted in EXPERIMENTS.md)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) * cost_repeat
    hbm = float(cost.get("bytes accessed", 0.0)) * cost_repeat
    coll = collective_bytes(lowered_text)
    coll.bytes_by_op = {k: v * cost_repeat
                        for k, v in coll.bytes_by_op.items()}
    mem = compiled.memory_analysis()
    peak = getattr(mem, "temp_size_in_bytes", 0) + \
        getattr(mem, "argument_size_in_bytes", 0) + \
        getattr(mem, "output_size_in_bytes", 0)
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops=flops, hbm_bytes=hbm, coll_bytes=float(coll.total_bytes),
        coll_detail={"bytes": coll.bytes_by_op, "count": coll.count_by_op},
        peak_memory_bytes=float(peak),
        model_flops=model_flops_for(cfg, shape),
    )
