import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512")
# Unroll layer stacks so cost_analysis counts every layer (XLA does not
# multiply while-loop bodies by trip count) — dry-run lowering only.
os.environ["REPRO_UNROLL"] = "1"

"""Multi-pod dry-run: .lower().compile() for every (arch × shape × mesh).

Proves the distribution config is coherent without hardware: sharding
propagation succeeds, the compiled module fits memory, and the roofline
terms (EXPERIMENTS.md §Roofline) are extracted from the artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
        --shape train_4k --mesh single          # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all  # all 80 cells
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_proof_cell(arch_name: str, shape_name: str, mesh_name: str,
                   *, save: bool = True) -> dict:
    """Scan-form-only compile proof: fast .lower().compile() check (the
    required dry-run gate) + memory_analysis.  Roofline terms come from
    the separate unrolled pass (run_cell)."""
    cfg = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        out = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": why}
    else:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
        t0 = time.time()
        try:
            os.environ["REPRO_UNROLL"] = "0"
            jax.clear_caches()
            fn, structs, in_sh, out_sh, meta = make_step(
                cfg, mesh, shape, dtype=jnp.bfloat16)
            with mesh:
                compiled = jax.jit(fn, in_shardings=in_sh,
                                   out_shardings=out_sh
                                   ).lower(*structs).compile()
            mem = compiled.memory_analysis()
            peak = (mem.temp_size_in_bytes + mem.argument_size_in_bytes +
                    mem.output_size_in_bytes)
            out = {"arch": arch_name, "shape": shape_name,
                   "mesh": mesh_name, "status": "ok", "meta": meta,
                   "compile_s": round(time.time() - t0, 1),
                   "peak_memory_bytes": float(peak),
                   "memory_analysis": str(mem)}
            print(f"[proof {arch_name} × {shape_name} × {mesh_name}] OK "
                  f"peak={peak / 2**30:.2f}GiB "
                  f"({out['compile_s']}s)")
        except Exception as e:
            out = {"arch": arch_name, "shape": shape_name,
                   "mesh": mesh_name, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-3000:]}
            print(f"[proof {arch_name} × {shape_name} × {mesh_name}] "
                  f"FAIL: {str(e)[:200]}")
    if save:
        d = os.path.join(OUT_DIR, "..", "proof")
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(
                d, f"{arch_name}__{shape_name}__{mesh_name}.json"),
                "w") as f:
            json.dump(out, f, indent=1)
    return out


def run_cell(arch_name: str, shape_name: str, mesh_name: str,
             *, save: bool = True, verbose: bool = True,
             variant: str = "", step_kwargs: dict | None = None) -> dict:
    """variant: perf-experiment tag — results saved under
    experiments/perf/ with the tag; step_kwargs forwarded to make_*_step
    (e.g. seq_parallel=False, num_micro=4)."""
    cfg = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = mesh.devices.size
    t0 = time.time()
    try:
        # Pass 1 — deployment form (lax.scan over layers): this is the
        # module you would actually run; its memory_analysis is the "fits"
        # proof (XLA reuses buffers across scan iterations).
        os.environ["REPRO_UNROLL"] = "0"
        jax.clear_caches()
        kw = step_kwargs or {}
        fn, structs, in_sh, out_sh, meta = make_step(cfg, mesh, shape,
                                                     dtype=jnp.bfloat16,
                                                     **kw)
        with mesh:
            compiled_scan = jax.jit(fn, in_shardings=in_sh,
                                    out_shardings=out_sh
                                    ).lower(*structs).compile()
        mem = compiled_scan.memory_analysis()
        t_scan = time.time() - t0

        # Pass 2 — unrolled form: XLA's cost_analysis does not multiply
        # while bodies by trip count, so FLOPs/bytes/collectives come from
        # a layer-unrolled lowering of the SAME computation.
        os.environ["REPRO_UNROLL"] = "1"
        jax.clear_caches()
        fn, structs, in_sh, out_sh, meta = make_step(cfg, mesh, shape,
                                                     dtype=jnp.bfloat16,
                                                     **kw)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*structs)
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_scan

        report = roofline.analyze(
            compiled, compiled.as_text(), arch=arch_name, shape=shape,
            mesh_name=mesh_name, chips=chips, cfg=cfg,
            cost_repeat=meta.get("cost_repeat", 1))
        # memory from the deployment (scan) module
        report.peak_memory_bytes = float(
            mem.temp_size_in_bytes + mem.argument_size_in_bytes +
            mem.output_size_in_bytes)
        out = {"status": "ok", "scan_compile_s": round(t_scan, 1),
               "unroll_compile_s": round(t_compile, 1), "meta": meta,
               "variant": variant or "baseline",
               "memory_analysis": str(mem), **report.to_dict()}
        if verbose:
            print(f"[{arch_name} × {shape_name} × {mesh_name}] OK "
                  f"compute={report.compute_s:.4f}s "
                  f"memory={report.memory_s:.4f}s "
                  f"collective={report.collective_s:.4f}s "
                  f"bottleneck={report.bottleneck} mfu={report.mfu:.3f}")
            print(f"  peak-mem/device={report.peak_memory_bytes/2**30:.2f}GiB"
                  f"  useful-flops={report.useful_flops_ratio:.2f}")
    except Exception as e:
        out = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
        if verbose:
            print(f"[{arch_name} × {shape_name} × {mesh_name}] FAIL: "
                  f"{type(e).__name__}: {str(e)[:300]}")
    if save:
        out_dir = OUT_DIR if not variant else \
            os.path.join(OUT_DIR, "..", "perf")
        os.makedirs(out_dir, exist_ok=True)
        tag = f"__{variant}" if variant else ""
        path = os.path.join(
            out_dir, f"{arch_name}__{shape_name}__{mesh_name}{tag}.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--proof-only", action="store_true",
                    help="scan-form compile proof only (fast)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        meshes = ["single", "multipod"]

    results = []
    for a in archs:
        for s in shapes:
            for m in meshes:
                if args.proof_only:
                    p = os.path.join(OUT_DIR, "..", "proof",
                                     f"{a}__{s}__{m}.json")
                    if args.skip_existing and os.path.exists(p):
                        with open(p) as f:
                            results.append(json.load(f))
                        continue
                    results.append(run_proof_cell(a, s, m))
                    continue
                path = os.path.join(OUT_DIR, f"{a}__{s}__{m}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[{a} × {s} × {m}] cached "
                              f"({prev['status']})")
                        results.append(prev)
                        continue
                results.append(run_cell(a, s, m))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n=== dry-run: {n_ok} ok / {n_skip} skipped / {n_err} failed "
          f"of {len(results)} cells ===")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
