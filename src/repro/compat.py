"""Version-compatibility shims for the pinned jax.

``jax.shard_map`` only exists from jax 0.5; the container pins jax 0.4.37
where the API lives at ``jax.experimental.shard_map.shard_map``.  Import it
from here so call sites work on both:

    from repro.compat import shard_map

The wrapper also normalizes the replication-check flag: callers pass
``check_rep=`` (the 0.4.x name), which newer jax renamed ``check_vma=``
and may drop entirely — the shim translates or drops it to match whatever
the underlying implementation accepts.
"""

from __future__ import annotations

import inspect

import jax

try:
    _shard_map_impl = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = frozenset(
    inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *args, **kwargs):
    if "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        flag = kwargs.pop("check_rep")
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = flag
    return _shard_map_impl(f, *args, **kwargs)


__all__ = ["shard_map"]
