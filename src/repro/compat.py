"""Version-compatibility shims for the pinned jax.

``jax.shard_map`` only exists from jax 0.5; the container pins jax 0.4.37
where the API lives at ``jax.experimental.shard_map.shard_map``.  Import it
from here so call sites work on both:

    from repro.compat import shard_map
"""

from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map

__all__ = ["shard_map"]
