"""Tiered index layout: heat-driven hot/warm/cold placement over a static
FaTRQ index (the paper's tiered-memory story turned adaptive).

``TieredIndex`` wraps an immutable ``FaTRQIndex`` with a per-list placement
array driven by ``memory.placement``:

  hot   lists' rows live fully in HBM — the executor scores them exactly
        against the full-precision vectors and SKIPS progressive
        refinement for them (billed ``hot:hbm``),
  warm  lists run today's fused TRQ path unchanged (``refine:cxl``),
  cold  lists' residual stream — level 0 and every deeper level — is
        demoted to SSD rates (``cold:ssd``).

The datapath split happens per CANDIDATE, not per query: the
``TieredFrontStage`` wrapper annotates the inner front's candidate batch
with per-row tier codes (one device gather) plus a per-list access
counter, and the executor routes on the codes (``executor._refine_rerank``
/ ``fold_counts``).  With every list WARM — the initial placement, and the
forced placement when ``TieredConfig(enabled=False)`` — the annotations
are all-identity and the tiered layout is bit-identical to the static
layout: same ids, same distances, same ledger.

Heat flows back without extra work: the executor's one counter transfer
per search already carries the per-list candidate counts (``list_heat``),
which ``TieredIndex.observe_heat`` folds into an EMA ``HeatTracker``.
Migration is EXPLICIT — ``rebalance_tiers()`` re-plans placement against
the occupancy budgets and, exactly like the streaming index's
``compact()``/``rebalance()``, bumps the index generation and fires the
generation hooks so the plan-keyed executor cache (``make_executor``) and
the serving result cache (``serving.cache.ResultCache``) invalidate.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns import registry
from repro.anns.pipeline import FaTRQIndex
from repro.anns.stages import (Candidates, Counters, make_graph_front,
                               make_ivf_front)
from repro.memory import QueryCost, RecordLayout
from repro.memory.placement import (TIER_COLD, TIER_HOT, TIER_WARM,
                                    HeatTracker, TieredConfig, occupancy,
                                    plan_migration, plan_placement)
from repro.obs import metrics as obs_metrics, trace


class TieredIndex:
    """A static FaTRQ index + per-list hot/warm/cold placement.

    Quacks like ``FaTRQIndex`` (``config``/``codebook``/``pq_codes``/
    ``ivf``/``trq``/``x``/``layout`` are the wrapped index's own arrays —
    placement never copies or re-encodes rows), and like
    ``StreamingIndex`` for the invalidation surface (``generation``,
    ``add_generation_hook``).
    """

    def __init__(self, index: FaTRQIndex,
                 tiered: TieredConfig | None = None):
        self.inner = index
        self.tiered = tiered if tiered is not None else TieredConfig()
        self.config = index.config
        self.codebook = index.codebook
        self.pq_codes = index.pq_codes
        self.ivf = index.ivf
        self.trq = index.trq
        self.x = index.x
        self.layout: RecordLayout = index.layout

        nlist = int(self.config.nlist)
        lists = np.asarray(index.ivf.lists)
        cap = lists.shape[1]
        n_rows = int(index.x.shape[0])
        # row → owning IVF list (vectorized inverse of the list table)
        rl = np.zeros(n_rows, np.int32)
        li_idx = np.repeat(np.arange(nlist, dtype=np.int32), cap)
        flat = lists.ravel()
        m = flat >= 0
        rl[flat[m]] = li_idx[m]
        self.row_list = rl
        self.list_rows = np.asarray(index.ivf.list_len, np.int64).copy()
        self.list_tier = np.full(nlist, TIER_WARM, np.int8)  # all-warm start
        self.heat = HeatTracker(nlist, decay=self.tiered.decay)
        self.generation = 0
        self._gen_hooks: list = []
        self._dev_cache: dict | None = None

    # ----------------------------------------------------- heat + migration

    def observe_heat(self, counts) -> None:
        """Fold one search batch's per-list candidate counts (the
        ``list_heat`` counter the executor pops out of ``fold_counts``)
        into the EMA tracker.  Deterministic given the query trace."""
        self.heat.observe(np.asarray(counts))

    def rebalance_tiers(self, *, force: bool = False) -> dict:
        """Re-plan placement against the occupancy budgets and migrate.

        Returns a report ``{"changed", "moves", "occupancy",
        "generation"}``.  The generation bumps ONLY when the placement
        actually changed — an unchanged plan must not evict warm executor
        caches or serving result-cache entries.  ``force`` overrides the
        ``min_observations`` gate, not the no-change short-circuit.
        """
        if not force and self.heat.observations < self.tiered.min_observations:
            return {"changed": False, "moves": {},
                    "occupancy": occupancy(self.list_tier, self.list_rows),
                    "generation": self.generation}
        new = plan_placement(self.heat.heat, self.list_rows, self.tiered)
        moves = plan_migration(self.list_tier, new, self.list_rows)
        changed = bool(moves)
        if changed:
            self.list_tier = new
            self._invalidate()
        occ = occupancy(self.list_tier, self.list_rows)
        self._observe_rebalance(moves, occ)
        return {"changed": changed, "moves": moves, "occupancy": occ,
                "generation": self.generation}

    # ------------------------------------------------- generation surface

    def add_generation_hook(self, fn) -> None:
        """Register ``fn(index, generation)`` to fire after every
        placement migration — same contract as
        ``StreamingIndex.add_generation_hook`` (the serving result cache
        attaches here)."""
        self._gen_hooks.append(fn)

    def _invalidate(self) -> None:
        self.generation += 1
        self._dev_cache = None
        for fn in list(self._gen_hooks):
            fn(self, self.generation)

    def _observe_rebalance(self, moves: dict, occ: dict) -> None:
        reg = obs_metrics.active()
        rows_total = max(int(self.list_rows.sum()), 1)
        heat_total = float(self.heat.heat.sum())
        for name, (nlists, nrows) in occ.items():
            reg.gauge("tiered_rows", "rows per placement tier",
                      labelnames=("tier",)).labels(tier=name).set(nrows)
            reg.gauge("tiered_lists", "IVF lists per placement tier",
                      labelnames=("tier",)).labels(tier=name).set(nlists)
            if heat_total > 0.0:
                tiers_np = np.asarray(self.list_tier)
                share = float(self.heat.heat[
                    tiers_np == {"hot": TIER_HOT, "warm": TIER_WARM,
                                 "cold": TIER_COLD}[name]].sum()) / heat_total
                # heat share vs row share: >1 for hot tiers means the
                # placement concentrates traffic onto few rows — the
                # adaptive win the policy is chasing
                row_share = occ[name][1] / rows_total
                reg.histogram(
                    "tiered_heat_row_ratio",
                    "per-tier EMA-heat share over row share",
                    labelnames=("tier",),
                    buckets=(0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0),
                ).labels(tier=name).observe(
                    share / row_share if row_share > 0 else 0.0)
        for (src, dst), rows in moves.items():
            reg.counter("tiered_migrations_total",
                        "rows migrated between placement tiers",
                        labelnames=("transition",)).labels(
                            transition=f"{src}->{dst}").inc(rows)
        if trace.active() is not None:
            trace.event("index.rebalance_tiers", track="index",
                        generation=self.generation,
                        moved_rows=sum(moves.values()),
                        **{f"rows_{n}": r for n, (_, r) in occ.items()})

    # ----------------------------------------------------- device arrays

    def _dev(self) -> dict:
        """Per-generation device cache of the placement gathers the front
        wrapper needs (same pattern as ``StreamingIndex._dev``)."""
        if self._dev_cache is None or \
                self._dev_cache["gen"] != self.generation:
            self._dev_cache = {
                "gen": self.generation,
                "row_tier": jnp.asarray(self.list_tier[self.row_list]),
                "row_list": jnp.asarray(self.row_list),
            }
        return self._dev_cache


# ------------------------------------------------------------- front stage


@partial(jax.jit, static_argnames=("nlist",))
def _tier_annotate(ids, valid, row_tier, row_list, *, nlist: int):
    """Per-candidate tier codes + the per-list access histogram (the heat
    signal), one gather + one scatter-add per micro-batch.  Padded and
    invalid candidate slots contribute nothing."""
    tier = row_tier[ids]
    hot = valid & (tier == TIER_HOT)
    cold = valid & (tier == TIER_COLD)
    heat = jnp.zeros((nlist,), jnp.int32).at[row_list[ids]].add(
        valid.astype(jnp.int32))
    counters: Counters = {"hot_cand": jnp.sum(hot),
                          "cold_cand": jnp.sum(cold),
                          "list_heat": heat}
    return tier, counters


@dataclass
class TieredFrontStage:
    """Wraps any registered front stage with placement annotation.

    The inner front's candidate generation, scoring and cost fold are
    untouched — this stage only gathers per-candidate tier codes and emits
    the ``hot_cand``/``cold_cand``/``list_heat`` counters the executor's
    tier routing and the heat tracker consume."""

    inner: object
    row_tier: jax.Array
    row_list: jax.Array
    nlist: int

    def __post_init__(self):
        self.name = self.inner.name

    def candidates(self, queries: jax.Array,
                   qvalid: jax.Array | None = None) -> Candidates:
        cand = self.inner.candidates(queries, qvalid)
        tier, counters = _tier_annotate(cand.ids, cand.valid, self.row_tier,
                                        self.row_list, nlist=self.nlist)
        return cand._replace(tier=tier,
                             counters={**cand.counters, **counters})

    def fold_cost(self, cost: QueryCost, counts: dict[str, int],
                  layout: RecordLayout) -> None:
        self.inner.fold_cost(cost, counts, layout)


# ----------------------------------------------------- registry integration
# Both fronts declare tiered support in ``anns.stages``; the factories wrap
# the STATIC stage builders — ``TieredIndex`` quacks like ``FaTRQIndex``,
# so the inner stages bind the wrapped index's arrays directly.


def _wrap_front(ti: TieredIndex, inner) -> TieredFrontStage:
    dev = ti._dev()
    return TieredFrontStage(inner=inner, row_tier=dev["row_tier"],
                            row_list=dev["row_list"],
                            nlist=int(ti.config.nlist))


def make_tiered_ivf_front(ti: TieredIndex, **opts) -> TieredFrontStage:
    return _wrap_front(ti, make_ivf_front(ti, **opts))


def make_tiered_graph_front(ti: TieredIndex, **opts) -> TieredFrontStage:
    return _wrap_front(ti, make_graph_front(ti, **opts))


registry.add_front_factory("ivf", "tiered", make_tiered_ivf_front)
registry.add_front_factory("graph", "tiered", make_tiered_graph_front)
