"""Pluggable search stages for the staged executor (paper Fig. 5).

The search path is an explicit three-stage pipeline over query
micro-batches — no per-query Python closures anywhere:

  front   : candidate generation + coarse ADC scoring in fast memory.
            Two interchangeable implementations: ``IVFFrontStage`` (inverted
            lists, the paper's primary front) and ``GraphFrontStage``
            (CAGRA-style beam search over PQ reconstructions).
  refine  : FaTRQ progressive estimation over the candidate batch, streaming
            packed ternary codes from far memory.  Two backends with
            identical semantics: ``ReferenceRefineBackend`` (pure-jnp
            ``core.estimator`` / ``trq.progressive_search`` math) and
            ``PallasRefineBackend`` (the persistent
            ``kernels.ternary_refine_fused`` kernel: ALL TRQ levels, the
            certified bounds, the alive-mask chain and the per-level
            survivor counters in one ``pallas_call`` per micro-batch).
  rerank  : survivors fetch full-precision vectors ("SSD") for exact L2.

Every stage returns *device-side* counters (0-d int32 arrays) alongside its
arrays; the executor folds them into a ``memory.QueryCost`` ledger with one
host transfer per search call (see ``executor.py``).  Stages also own their
traffic model via ``fold_cost`` so the executor stays backend-agnostic.

The streaming subsystem (``anns.streaming``) reuses the same pieces: its
generation-aware fronts (base ∪ delta IVF probe, tombstone-aware graph
traversal) emit the extra ``delta_cand`` counter (delta-row candidates,
billed to a distinct far-memory ledger entry) and both refine backends
score base and delta rows in one candidate batch — the
``Candidates``/``Refined`` contracts are unchanged.  The sharded
subsystem (``anns.sharding``) likewise inlines both fronts in its
shard_map body through ``registry.ShardedFrontHooks``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.anns import registry
from repro.core import trq as trq_mod
from repro.core.estimator import pooled_k_smallest
from repro.core.trq import TRQCodes
from repro.index import graph as graph_mod
from repro.index import ivf as ivf_mod
from repro.kernels import ops as kernel_ops
from repro.memory import QueryCost, RecordLayout, Tier
from repro.quant import pq as pq_mod

Counters = dict[str, jax.Array]     # name → 0-d device counter


class Candidates(NamedTuple):
    """Front-stage output for a query micro-batch.

    ``is_delta`` marks candidates living in delta spill pages (streaming
    fronts populate it; static/sharded fronts leave it ``None``) so the
    refine backends can split per-level survivor traffic for the ledger.

    ``tier`` carries per-candidate placement codes (``memory.placement``
    TIER_* values) on the tiered layout; every other front leaves it
    ``None``.  The executor — not the refine backends — consumes it: hot
    candidates detour to exact HBM scoring, cold candidates' residual
    stream is re-billed at SSD rates via ``is_delta``-style marking.
    """

    ids: jax.Array        # (Q, C) int32, clamped ≥ 0
    valid: jax.Array      # (Q, C) bool
    d0: jax.Array         # (Q, C) f32 coarse ADC distance, +inf if invalid
    counters: Counters
    is_delta: jax.Array | None = None   # (Q, C) bool, or None
    tier: jax.Array | None = None       # (Q, C) int8 TIER_* codes, or None


class Refined(NamedTuple):
    """Refine-stage output: calibrated estimates + survivor mask."""

    est: jax.Array        # (Q, C) f32
    alive: jax.Array      # (Q, C) bool (already ∧ valid)
    counters: Counters


@runtime_checkable
class FrontStage(Protocol):
    """Candidate generation: batched queries in, Candidates out.

    ``qvalid`` is an optional per-query validity mask (Q,) used by the
    bucket-padded entry points (``executor.pad_chunk``): padded query rows
    must contribute NOTHING to the candidate set or the device-side
    counters, so batched ledgers stay bit-identical to the sum of the
    real queries' unpadded ledgers.  ``None`` means all queries are real
    (the legacy trace).
    """

    name: str

    def candidates(self, queries: jax.Array,
                   qvalid: jax.Array | None = None) -> Candidates: ...

    def fold_cost(self, cost: QueryCost, counts: dict[str, int],
                  layout: RecordLayout) -> None: ...


@runtime_checkable
class RefineBackend(Protocol):
    """FaTRQ refinement over a candidate batch.

    ``axis_name`` selects sharded operation: inside ``shard_map`` the
    pruning thresholds are computed globally across the named mesh axis so
    per-shard survivor masks match an unsharded run exactly (see
    ``anns.sharding``).
    """

    name: str

    def refine(self, queries: jax.Array, cand: Candidates, trq: TRQCodes,
               *, k: int, bound: str, z: float,
               axis_name: str | None = None) -> Refined: ...


# ------------------------------------------------------------- front stages


def fold_ivf_front_cost(cost: QueryCost, counts: dict[str, int],
                        layout: RecordLayout) -> None:
    """IVF front traffic model: PQ codes + LUT live in fast memory (HBM).

    Shared by ``IVFFrontStage.fold_cost``, the per-shard fold in
    ``anns.sharding``, and the streaming front in ``anns.streaming`` (both
    are IVF-only), so the ledgers cannot drift apart.  ``front_cand``
    counts base AND delta candidates — delta rows' PQ codes are appended
    into the same fast-memory store; only their far-memory stream is
    billed separately (the ``delta_cand`` counter in
    ``executor.fold_counts``).
    """
    cost.record("coarse", Tier.HBM, counts["front_cand"], layout.fast_bytes)


def rank_centroid_lists(centroids: jax.Array, queries: jax.Array, *,
                        nprobe: int) -> tuple[jax.Array, jax.Array]:
    """Squared-L2 centroid ranking → (distances (Q, nlist), global
    top-nprobe list ids (Q, nprobe)).

    Shared by the unsharded IVF front and the sharded front
    (``anns.sharding``) — the sharded path's bit-identical guarantee
    depends on both selecting the same probe set.
    """
    d = jnp.sum((queries[:, None, :] - centroids[None]) ** 2, axis=-1)
    _, top_lists = jax.lax.top_k(-d, nprobe)
    return d, top_lists


def adc_score(codebook: pq_mod.PQCodebook, codes: jax.Array,
              queries: jax.Array, valid: jax.Array) -> jax.Array:
    """Batched PQ-ADC scoring of per-query gathered codes (Q, C, M),
    +inf outside ``valid``.  Shared with the sharded front likewise."""
    tables = jax.vmap(lambda q: pq_mod.adc_table(codebook, q))(queries)
    d0 = jax.vmap(pq_mod.adc_distances)(tables, codes)
    return jnp.where(valid, d0, jnp.inf)


@partial(jax.jit, static_argnames=("nprobe",))
def _ivf_candidates(ivf: ivf_mod.IVFIndex, codebook, pq_codes, queries,
                    qvalid, *, nprobe: int):
    _, top_lists = rank_centroid_lists(ivf.centroids, queries,
                                       nprobe=nprobe)
    ids = ivf.lists[top_lists].reshape(queries.shape[0], -1)  # (Q, nprobe·cap)
    valid = ids >= 0
    if qvalid is not None:                 # padded rows: no candidates
        valid = valid & qvalid[:, None]
    safe = jnp.maximum(ids, 0)
    d0 = adc_score(codebook, pq_codes[safe], queries, valid)
    return safe, valid, d0, jnp.sum(valid)


@dataclass
class IVFFrontStage:
    """Inverted-file probe + PQ-ADC scoring (the paper's primary front)."""

    ivf: ivf_mod.IVFIndex
    codebook: pq_mod.PQCodebook
    pq_codes: jax.Array
    nprobe: int = 8
    name: str = field(default="ivf", init=False)

    def candidates(self, queries: jax.Array,
                   qvalid: jax.Array | None = None) -> Candidates:
        safe, valid, d0, n_cand = _ivf_candidates(
            self.ivf, self.codebook, self.pq_codes, queries, qvalid,
            nprobe=self.nprobe)
        return Candidates(ids=safe, valid=valid, d0=d0,
                          counters={"front_cand": n_cand})

    def fold_cost(self, cost: QueryCost, counts: dict[str, int],
                  layout: RecordLayout) -> None:
        fold_ivf_front_cost(cost, counts, layout)


@partial(jax.jit, static_argnames=("iters", "beam", "expand"))
def _graph_candidates(neighbors, x_score, codebook, pq_codes, queries,
                      qvalid, *, iters: int, beam: int, expand: int):
    gidx = graph_mod.GraphIndex(neighbors=neighbors)
    ids = jax.vmap(lambda q: graph_mod.search(gidx, x_score, q, iters=iters,
                                              beam=beam, expand=expand))(
        queries)                                              # (Q, beam)
    valid = jnp.ones(ids.shape, bool) if qvalid is None \
        else jnp.broadcast_to(qvalid[:, None], ids.shape)
    tables = jax.vmap(lambda q: pq_mod.adc_table(codebook, q))(queries)
    d0 = jax.vmap(pq_mod.adc_distances)(tables, pq_codes[ids])
    d0 = jnp.where(valid, d0, jnp.inf)
    return ids, valid, d0, jnp.sum(valid)


def fold_graph_front_cost(cost: QueryCost, counts: dict[str, int],
                          layout: RecordLayout) -> None:
    """Graph front traffic model: beam traversal decodes PQ codes of the
    visited neighborhoods (``front_hops``), then the final beam is
    ADC-scored (``front_cand``) — all fast-memory traffic.  Shared by
    ``GraphFrontStage.fold_cost``, the per-shard fold in ``anns.sharding``
    and the streaming graph front (``anns.streaming``), so the three
    datapaths' ledgers cannot drift apart."""
    cost.record("front", Tier.HBM, counts["front_hops"], layout.fast_bytes)
    cost.record("coarse", Tier.HBM, counts["front_cand"], layout.fast_bytes)


@dataclass
class GraphFrontStage:
    """CAGRA-style beam search scored on PQ reconstructions.

    Traversal distances use the fast-memory PQ decode (no SSD touches); the
    resulting beam is handed to refinement exactly like an IVF candidate
    list.  ``hops`` counts graph-adjacency PQ fetches during traversal.
    """

    graph: graph_mod.GraphIndex
    codebook: pq_mod.PQCodebook
    pq_codes: jax.Array
    beam: int = 64
    iters: int = 32
    expand: int = 4
    name: str = field(default="graph", init=False)
    x_score: jax.Array = field(init=False)

    def __post_init__(self):
        self.x_score = pq_mod.decode(self.codebook, self.pq_codes)

    def candidates(self, queries: jax.Array,
                   qvalid: jax.Array | None = None) -> Candidates:
        ids, valid, d0, n_cand = _graph_candidates(
            self.graph.neighbors, self.x_score, self.codebook, self.pq_codes,
            queries, qvalid, iters=self.iters, beam=self.beam,
            expand=self.expand)
        # traversal work is uniform per query, so padded rows just scale out
        per_q = self.iters * self.expand * self.graph.degree
        nq = jnp.asarray(queries.shape[0], jnp.int32) if qvalid is None \
            else jnp.sum(qvalid).astype(jnp.int32)
        return Candidates(ids=ids, valid=valid, d0=d0,
                          counters={"front_cand": n_cand,
                                    "front_hops": nq * per_q})

    def fold_cost(self, cost: QueryCost, counts: dict[str, int],
                  layout: RecordLayout) -> None:
        fold_graph_front_cost(cost, counts, layout)


# ---------------------------------------------------------- refine backends


def _level_counters(level_alive: tuple[jax.Array, ...],
                    is_delta: jax.Array | None = None) -> Counters:
    """Per-level survivor counters from the alive-mask chain.

    ``refine_alive`` is the FINAL survivor count (kept for the single-level
    ledger and back-compat); ``refine_alive_l{ℓ}`` counts the candidates
    ENTERING level ℓ ≥ 1 — i.e. survivors of level ℓ−1 — which is exactly
    the population whose level-ℓ codes stream from far memory.  When the
    front marks delta-page candidates, ``refine_alive_l{ℓ}_delta`` is the
    delta-resident share of that population, so the executor can bill it
    to the delta spill stream instead of the base residual store.
    """
    counters: Counters = {"refine_alive": jnp.sum(level_alive[-1])}
    for lv in range(1, len(level_alive)):
        counters[f"refine_alive_l{lv}"] = jnp.sum(level_alive[lv - 1])
        if is_delta is not None:
            counters[f"refine_alive_l{lv}_delta"] = jnp.sum(
                level_alive[lv - 1] & is_delta)
    return counters


@partial(jax.jit, static_argnames=("k", "bound", "z", "axis_name"))
def _reference_refine(queries, d0, ids, valid, trq: TRQCodes, *, k: int,
                      bound: str, z: float, axis_name: str | None = None):
    def one(q, d0_q, ids_q):
        state, level_alive = trq_mod.progressive_search(
            q, d0_q, trq, ids_q, k=k, bound=bound, z=z, axis_name=axis_name,
            collect_level_alive=True)
        return state.est, level_alive

    est, level_alive = jax.vmap(one)(queries, d0, ids)
    level_alive = tuple(a & valid for a in level_alive)
    return est, level_alive


@dataclass
class ReferenceRefineBackend:
    """Pure-jnp estimator path (``core.estimator`` via progressive_search)."""

    name: str = field(default="reference", init=False)

    def refine(self, queries: jax.Array, cand: Candidates, trq: TRQCodes,
               *, k: int, bound: str, z: float,
               axis_name: str | None = None) -> Refined:
        est, level_alive = _reference_refine(
            queries, cand.d0, cand.ids, cand.valid, trq, k=k, bound=bound,
            z=z, axis_name=axis_name)
        return Refined(est=est, alive=level_alive[-1],
                       counters=_level_counters(level_alive, cand.is_delta))


def _topk_threshold_batch(hi: jax.Array, alive: jax.Array, k: int,
                          axis_name: str | None = None) -> jax.Array:
    """Batched kth-smallest upper estimate among alive candidates (Q,).

    With ``axis_name`` (inside shard_map) the threshold is global — the
    shared ``estimator.pooled_k_smallest`` pooling, batched over queries.
    """
    masked = jnp.where(alive, hi, jnp.inf)
    return pooled_k_smallest(masked, k, axis_name)


@partial(jax.jit, static_argnames=("k", "bound", "z", "block_c",
                                   "axis_name"))
def _pallas_refine(queries, d0, ids, valid, is_delta, trq: TRQCodes, *,
                   k: int, bound: str, z: float, block_c: int,
                   axis_name: str | None = None):
    """Persistent fused refinement: ONE pallas_call per query micro-batch.

    All TRQ levels' packed codes and [proj, norm, rho] planes are gathered
    up front; the kernel walks them level-by-level with the running
    estimate / certified bounds / alive mask resident in VMEM scratch, so
    no intermediate estimates or masks round-trip through HBM.

    Unsharded (``axis_name=None``): the pruning threshold after each level
    is computed on-chip (SMEM carry) and the kernel directly returns the
    final estimates, survivor mask and per-level survivor counts.

    Sharded (inside shard_map): thresholds must be globally exact, so the
    kernel's bounds-emitting form returns every level's certified
    (lo, hi) from the same single launch and the alive chain runs here
    with ``pooled_k_smallest`` exchanging thresholds across ``axis_name``
    between level segments — bit-identical masks to the on-chip form.
    """
    sc = trq.scalars
    packed_levels = jnp.stack([lv.packed[ids] for lv in trq.levels])
    lvl_proj = jnp.stack([lv.proj[ids] for lv in trq.levels])
    lvl_norm = jnp.stack([lv.norm[ids] for lv in trq.levels])
    lvl_rho = jnp.stack([lv.rho[ids] for lv in trq.levels])
    delta_mask = jnp.zeros_like(valid) if is_delta is None else is_delta
    args = (packed_levels, queries, d0, sc.delta_sq[ids], sc.cross[ids],
            sc.norm[ids], sc.rho[ids], valid, delta_mask, lvl_proj,
            lvl_norm, lvl_rho, trq.model.w, trq.model.bias,
            trq.model.resid_std, z)

    if axis_name is None:
        est, alive, counts = kernel_ops.fused_refine_scores_batch(
            *args, k=k, bound=bound, block_c=block_c)
        nl = trq.num_levels
        counters: Counters = {"refine_alive": jnp.sum(counts[:, nl - 1])}
        for lv in range(1, nl):
            counters[f"refine_alive_l{lv}"] = jnp.sum(counts[:, lv - 1])
            if is_delta is not None:
                counters[f"refine_alive_l{lv}_delta"] = jnp.sum(
                    counts[:, nl + lv - 1])
        return est, alive, counters

    est, lo, hi = kernel_ops.fused_refine_bounds_batch(
        *args, bound=bound, block_c=block_c)
    alive = valid
    level_alive = []
    for lv in range(trq.num_levels):
        tau = _topk_threshold_batch(hi[:, lv], alive, k, axis_name)
        alive = alive & (lo[:, lv] <= tau[:, None])
        level_alive.append(alive)
    return est, alive, _level_counters(tuple(level_alive), is_delta)


@dataclass
class PallasRefineBackend:
    """Persistent fused-kernel path (``kernels.ternary_refine_fused``).

    The whole progressive-refinement loop — digit-plane unpack, per-level
    estimate stacking, certified margins, pruning thresholds, survivor
    masks and ledger counters — runs as a single ``pallas_call`` per query
    micro-batch (per shard when sharded).  Produces the same survivors and
    ledger as the reference backend; on CPU containers the kernel runs in
    interpret mode.
    """

    block_c: int = 512
    name: str = field(default="pallas", init=False)

    def refine(self, queries: jax.Array, cand: Candidates, trq: TRQCodes,
               *, k: int, bound: str, z: float,
               axis_name: str | None = None) -> Refined:
        est, alive, counters = _pallas_refine(
            queries, cand.d0, cand.ids, cand.valid, cand.is_delta, trq,
            k=k, bound=bound, z=z, block_c=self.block_c,
            axis_name=axis_name)
        return Refined(est=est, alive=alive, counters=counters)


# ----------------------------------------------------------------- rerank


@partial(jax.jit, static_argnames=("k", "budget"))
def _rerank_survivors(x, queries, ids, est, alive, *, k: int, budget: int):
    """Batched exact rerank: top-`budget` survivors by estimate fetch full
    vectors, exact L2, top-k.  Returns (topk_ids, topk_dists, n_ssd) —
    distances are the exact squared L2 of each returned id (+inf on padded
    slots when fewer than k candidates survived)."""
    est_m = jnp.where(alive, est, jnp.inf)
    _, order = jax.lax.top_k(-est_m, budget)                  # (Q, budget)
    fetch_ids = jnp.take_along_axis(ids, order, axis=1)
    fetch_alive = jnp.take_along_axis(alive, order, axis=1)
    d = jnp.sum((x[fetch_ids] - queries[:, None, :]) ** 2, axis=-1)
    d = jnp.where(fetch_alive, d, jnp.inf)
    neg_d, best = jax.lax.top_k(-d, k)
    topk = jnp.take_along_axis(fetch_ids, best, axis=1)
    return topk, -neg_d, jnp.sum(fetch_alive)


@jax.jit
def _score_hot(x, queries, ids, hot):
    """Exact squared-L2 for hot (HBM-resident) candidates, +inf elsewhere.
    The tiered layout's direct scoring path: full-precision rows of hot
    lists never left fast memory, so reading them costs HBM rates and the
    refinement cascade is skipped entirely for these candidates."""
    d = jnp.sum((x[ids] - queries[:, None, :]) ** 2, axis=-1)
    return jnp.where(hot, d, jnp.inf)


@partial(jax.jit, static_argnames=("k", "budget"))
def _rerank_survivors_tiered(x, queries, ids, est, alive, hot, *, k: int,
                             budget: int):
    """``_rerank_survivors`` for the tiered layout: identical ids and
    distances, but hot candidates' full vectors are already HBM-resident —
    their fetches must not bill to the SSD rerank counter.  Returns
    (topk_ids, topk_dists, n_ssd, n_hot_fetch)."""
    est_m = jnp.where(alive, est, jnp.inf)
    _, order = jax.lax.top_k(-est_m, budget)
    fetch_ids = jnp.take_along_axis(ids, order, axis=1)
    fetch_alive = jnp.take_along_axis(alive, order, axis=1)
    fetch_hot = jnp.take_along_axis(hot, order, axis=1) & fetch_alive
    d = jnp.sum((x[fetch_ids] - queries[:, None, :]) ** 2, axis=-1)
    d = jnp.where(fetch_alive, d, jnp.inf)
    neg_d, best = jax.lax.top_k(-d, k)
    topk = jnp.take_along_axis(fetch_ids, best, axis=1)
    return (topk, -neg_d, jnp.sum(fetch_alive & ~fetch_hot),
            jnp.sum(fetch_hot))


@partial(jax.jit, static_argnames=("k",))
def _rerank_all(x, queries, ids, valid, *, k: int):
    """Baseline rerank: exact L2 over the whole candidate list (no refine).
    Returns (topk_ids, topk_dists, n_valid)."""
    d = jnp.sum((x[ids] - queries[:, None, :]) ** 2, axis=-1)
    d = jnp.where(valid, d, jnp.inf)
    neg_d, best = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(ids, best, axis=1), -neg_d, jnp.sum(valid)


# ----------------------------------------------- front factories + registry
# Each front registers itself with the capability registry: supported index
# layouts plus a per-layout stage factory.  ``anns.streaming`` attaches the
# "streaming" factories (base ∪ delta IVF, tombstone-aware graph) and
# ``anns.tiered`` the "tiered" ones (tier-annotating wrappers) when they
# are imported; the "sharded" layout inlines its fronts in the shard_map
# body via ``registry.ShardedFrontHooks`` (``anns.sharding`` registers the
# whole-list LPT partitioner for IVF and the vector-range + halo
# partitioner for graph), so both fronts declare it here but register no
# stage factory for it.


def graph_for(index, *, degree: int = 16) -> graph_mod.GraphIndex:
    """Build (once per degree) and cache the kNN graph for an index's
    database.  The cache lives ON the index instance, so its lifetime is
    exactly the index's lifetime — no process-global registry to leak.
    Keyed by ``degree``: a degree-32 request must not silently return a
    previously cached degree-16 graph."""
    cache = getattr(index, "_graph_cache", None)
    if not isinstance(cache, dict):      # also migrates the pre-dict cache
        cache = {}
        index._graph_cache = cache
    g = cache.get(degree)
    if g is None:
        g = graph_mod.build(index.x, degree=degree)
        cache[degree] = g
    return g


def make_ivf_front(index, **opts) -> IVFFrontStage:
    nprobe = opts.pop("nprobe", index.config.nprobe)
    if opts:
        raise TypeError(f"unknown IVF front options: {sorted(opts)}")
    return IVFFrontStage(ivf=index.ivf, codebook=index.codebook,
                         pq_codes=index.pq_codes, nprobe=nprobe)


def make_graph_front(index, *, graph_index=None, degree: int = 16,
                     **opts) -> GraphFrontStage:
    g = graph_index if graph_index is not None \
        else graph_for(index, degree=degree)
    return GraphFrontStage(graph=g, codebook=index.codebook,
                           pq_codes=index.pq_codes, **opts)


registry.register_front("ivf",
                        layouts=("static", "sharded", "streaming", "tiered"),
                        make={"static": make_ivf_front})
registry.register_front("graph",
                        layouts=("static", "sharded", "streaming", "tiered"),
                        make={"static": make_graph_front})
registry.register_backend("reference", make=ReferenceRefineBackend)
registry.register_backend("pallas", make=PallasRefineBackend)
