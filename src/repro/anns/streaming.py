"""Streaming index subsystem: online inserts/deletes over a FaTRQ index.

The static pipeline (``build`` → ``SearchExecutor``) assumes an immutable
``(N, …)`` database.  A production RAG service ingests embeddings
continuously, so ``StreamingIndex`` makes the tiered layout MUTABLE without
a full rebuild (FreshDiskANN-style delta maintenance adapted to FaTRQ's
far-memory layout):

* **Row store** — every per-record array (PQ codes, TRQ levels + scalars,
  full vectors) lives in a capacity-padded device array; inserts append
  rows with ``lax.dynamic_update_slice`` (``trq.write_rows``), never
  touching existing rows, and the store doubles host-side when full.
  New rows are TRQ-encoded INCREMENTALLY (``trq.encode_rows``) against the
  frozen quantizers — per-record quantities are row-independent, so the
  appended codes are bit-identical to a full re-encode.

* **Delta lists** — per-IVF-list fixed-capacity spill pages of freshly
  inserted row ids, -1 padded so the datapath stays jit/shard_map-able.
  A full page grows by whole pages (shape change → one retrace).  The
  front stage probes base lists ∪ delta lists of the same top-``nprobe``
  centroids; delta candidates are counted separately (``delta_cand``) and
  their far-memory stream is billed to a DISTINCT ``delta:cxl`` ledger
  entry (``executor.fold_counts``).

* **Tombstones** — ``delete(gids)`` flips an alive bitmap; dead rows are
  masked out of the candidate set in the front stage (and therefore never
  reach refine/rerank).  Ids returned by ``search`` are stable GLOBAL ids
  (``row_gid``), monotonic across the index's lifetime.

* **Graph front** — ``search(front="graph")`` runs the CAGRA-style beam
  traversal over the mutable row store.  The adjacency is materialized
  lazily on first graph search and then maintained ONLINE
  (FreshDiskANN-style, ``index.graph``): ``insert`` wires each new row to
  its beam-search neighborhood (forward edges) and into its neighbors'
  reverse slots; ``delete`` leaves the graph alone — traversal routes
  THROUGH tombstoned rows, the front just masks them out of the candidate
  beam; ``compact()`` drops dead rows and patches edges through them with
  a one-hop contraction.  Rows appended since the last compaction count as
  ``delta_cand`` (their TRQ codes live in the delta region of far memory),
  so the graph front bills the same ``delta:cxl`` ledger entry the IVF
  base ∪ delta probe does.

* **Compaction / rebalancing** — when the drift metric crosses a
  threshold (tombstone fraction, delta fraction, or — once a shard
  assignment exists — the stale assignment's max shard load exceeding a
  fresh LPT partition's by more than the (4/3 − 1/3S) guarantee factor),
  ``compact()`` folds delta pages into freshly filled base lists
  (``ivf.fill_lists``), drops tombstones, and repacks the row store with
  one gather (``trq.gather_rows``); ``rebalance(shards)`` additionally
  re-partitions lists across shards with the same ``sharding.lpt_assign``
  greedy the static partitioner uses, reporting how many rows MOVED
  shards (moves are gathers of packed codes — TRQ codes are
  centroid-relative, so no row is ever re-encoded after insert).

Search equivalence: ``rebuild_static()`` assigns every surviving row from
scratch into fresh inverted lists (reusing the trained centroids/PQ/
calibration — retraining those on drifted data is a model update, not an
index-maintenance operation) and returns a plain ``FaTRQIndex`` + gid map.
``StreamingIndex.search`` matches its top-k exactly for both refine
backends — same probe set, same candidate SET (order differs, but every
pruning threshold is a kth-smallest over the same value multiset), same
survivors, same exact rerank — up to exact-f32 estimate ties at the
budget boundary (the same measure-zero caveat as ``anns.sharding``).
``search(shards=S)`` routes a snapshot through the sharded subsystem and
maps shard-local results back to global ids.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns import registry
from repro.anns.executor import SearchExecutor
from repro.anns.pipeline import FaTRQIndex, PipelineConfig
from repro.anns.sharding import lpt_assign
from repro.anns.stages import (Candidates, adc_score, fold_graph_front_cost,
                               fold_ivf_front_cost, rank_centroid_lists)
from repro.core import trq as trq_mod
from repro.index import graph as graph_mod
from repro.index import ivf as ivf_mod
from repro.memory import QueryCost
from repro.obs import metrics as obs_metrics, trace
from repro.quant import pq as pq_mod
from repro.quant.kmeans import assign


@dataclass(frozen=True)
class StreamingConfig:
    """Knobs of the mutable layer (the search knobs stay in
    ``PipelineConfig``)."""

    delta_page: int = 64           # slots per per-list delta spill page
    row_headroom: float = 0.25     # spare row capacity after grow/compact
    max_tombstone_frac: float = 0.3    # drift trigger: dead / (live+dead)
    max_delta_frac: float = 0.5        # drift trigger: delta rows / live
    auto_compact: bool = True      # fold automatically when drift trips


def _pad_rows(a: jax.Array, cap: int) -> jax.Array:
    """Zero-pad a per-record device array to ``cap`` leading rows."""
    pad = cap - a.shape[0]
    if pad <= 0:
        return a
    return jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], 0)


@partial(jax.jit, static_argnames=("nprobe",))
def _streaming_candidates(centroids, codebook, pq_codes, base_lists,
                          delta_lists, alive, queries, qvalid, *,
                          nprobe: int):
    """Generation-aware IVF front: probe base ∪ delta lists of the global
    top-``nprobe`` centroids, mask tombstones, ADC-score, and count delta
    candidates separately for the ledger."""
    _, top_lists = rank_centroid_lists(centroids, queries, nprobe=nprobe)
    nq = queries.shape[0]
    ids_b = base_lists[top_lists].reshape(nq, -1)
    ids_d = delta_lists[top_lists].reshape(nq, -1)
    ids = jnp.concatenate([ids_b, ids_d], axis=1)             # (Q, C)
    safe = jnp.maximum(ids, 0)
    valid = (ids >= 0) & alive[safe]                          # tombstone mask
    if qvalid is not None:                 # padded rows: no candidates
        valid = valid & qvalid[:, None]
    d0 = adc_score(codebook, pq_codes[safe], queries, valid)
    is_delta = jnp.broadcast_to(
        jnp.arange(ids.shape[1])[None, :] >= ids_b.shape[1], ids.shape)
    return (safe, valid, d0, is_delta, jnp.sum(valid),
            jnp.sum(valid & is_delta))


@dataclass
class StreamingFrontStage:
    """``FrontStage`` over a mutable generation: base ∪ delta probe with
    tombstone masking.  Implements the same protocol as ``IVFFrontStage``
    so the plain ``SearchExecutor`` runs the streaming datapath unchanged
    (its candidate ids are ROW ids — ``StreamingIndex.search`` maps the
    executor's output through ``row_gid``)."""

    centroids: jax.Array
    codebook: pq_mod.PQCodebook
    pq_codes: jax.Array
    base_lists: jax.Array
    delta_lists: jax.Array
    alive: jax.Array
    nprobe: int = 8
    name: str = "streaming"

    def candidates(self, queries: jax.Array,
                   qvalid: jax.Array | None = None) -> Candidates:
        safe, valid, d0, is_delta, n_cand, n_delta = _streaming_candidates(
            self.centroids, self.codebook, self.pq_codes, self.base_lists,
            self.delta_lists, self.alive, queries, qvalid,
            nprobe=self.nprobe)
        return Candidates(ids=safe, valid=valid, d0=d0,
                          counters={"front_cand": n_cand,
                                    "delta_cand": n_delta},
                          is_delta=is_delta)

    def fold_cost(self, cost: QueryCost, counts: dict[str, int],
                  layout) -> None:
        fold_ivf_front_cost(cost, counts, layout)


@partial(jax.jit, static_argnames=("iters", "beam", "expand", "n_base"))
def _graph_streaming_candidates(neighbors, x_score, codebook, pq_codes,
                                alive, queries, qvalid, *, iters: int,
                                beam: int, expand: int, n_base: int):
    """Tombstone-aware graph front: beam-search the maintained adjacency
    (which still routes THROUGH dead rows), mask tombstones out of the
    final beam, and count post-compaction rows as delta candidates."""
    gidx = graph_mod.GraphIndex(neighbors=neighbors)
    ids = jax.vmap(lambda q: graph_mod.search(gidx, x_score, q, iters=iters,
                                              beam=beam, expand=expand))(
        queries)                                              # (Q, beam)
    valid = alive[ids]
    if qvalid is not None:                 # padded rows: no candidates
        valid = valid & qvalid[:, None]
    d0 = adc_score(codebook, pq_codes[ids], queries, valid)
    is_delta = ids >= n_base
    return (ids, valid, d0, is_delta, jnp.sum(valid),
            jnp.sum(valid & is_delta))


@dataclass
class GraphStreamingFrontStage:
    """``FrontStage`` running the CAGRA-style traversal over a mutable
    generation: the online-maintained adjacency plus the alive bitmap.
    Post-compaction (no tombstones, no delta rows) its candidate stream is
    bit-identical to the static ``GraphFrontStage`` over ``rebuild_static``
    given the same adjacency — same beam search, same ADC scoring — which
    is exactly what the churn-equivalence pin tests."""

    graph: graph_mod.GraphIndex
    codebook: pq_mod.PQCodebook
    pq_codes: jax.Array        # (n_rows, M) — sliced to the live store
    alive: jax.Array           # (n_rows,) bool
    n_base: int                # rows ≥ n_base were inserted post-compact
    beam: int = 64
    iters: int = 32
    expand: int = 4
    name: str = "graph"
    x_score: jax.Array = None

    def __post_init__(self):
        if self.x_score is None:
            self.x_score = pq_mod.decode(self.codebook, self.pq_codes)

    def candidates(self, queries: jax.Array,
                   qvalid: jax.Array | None = None) -> Candidates:
        ids, valid, d0, is_delta, n_cand, n_delta = \
            _graph_streaming_candidates(
                self.graph.neighbors, self.x_score, self.codebook,
                self.pq_codes, self.alive, queries, qvalid,
                iters=self.iters, beam=self.beam, expand=self.expand,
                n_base=self.n_base)
        per_q = self.iters * self.expand * self.graph.degree
        nq = jnp.asarray(queries.shape[0], jnp.int32) if qvalid is None \
            else jnp.sum(qvalid).astype(jnp.int32)
        return Candidates(ids=ids, valid=valid, d0=d0,
                          counters={"front_cand": n_cand,
                                    "front_hops": nq * per_q,
                                    "delta_cand": n_delta},
                          is_delta=is_delta)

    def fold_cost(self, cost: QueryCost, counts: dict[str, int],
                  layout) -> None:
        fold_graph_front_cost(cost, counts, layout)


class StreamingIndex:
    """Mutable FaTRQ index: online inserts/deletes + drift-triggered
    compaction, searched through the existing refine backends.

    Host-side structures (inverted lists, delta pages, alive bitmap, gid
    maps) are numpy and mirrored to device lazily per generation; the
    heavy per-row payloads (PQ codes, TRQ codes, full vectors) live in
    capacity-padded device arrays mutated by append only.
    """

    def __init__(self, index: FaTRQIndex,
                 streaming: StreamingConfig | None = None):
        cfg = index.config
        scfg = streaming or StreamingConfig()
        n = int(index.x.shape[0])
        cap_rows = int(n * (1.0 + scfg.row_headroom)) + 1

        self.config: PipelineConfig = cfg
        self.scfg = scfg
        self.layout = index.layout
        self.codebook = index.codebook
        self.centroids = index.ivf.centroids
        self.nlist = index.ivf.nlist

        # device row store, capacity-padded
        self.pq_codes = _pad_rows(index.pq_codes, cap_rows)
        self.trq = trq_mod.TRQCodes(
            dim=index.trq.dim,
            levels=tuple(jax.tree.map(lambda a: _pad_rows(a, cap_rows), lv)
                         for lv in index.trq.levels),
            scalars=jax.tree.map(lambda a: _pad_rows(a, cap_rows),
                                 index.trq.scalars),
            model=index.trq.model)
        self.x = _pad_rows(index.x, cap_rows)

        # host index structures
        self.base_lists = np.asarray(index.ivf.lists).copy()
        self.base_len = np.asarray(index.ivf.list_len).copy()
        self.delta_lists = np.full((self.nlist, scfg.delta_page), -1,
                                   np.int32)
        self.delta_len = np.zeros((self.nlist,), np.int32)
        self.row_gid = np.full((cap_rows,), -1, np.int64)
        self.row_gid[:n] = np.arange(n)
        self.alive = np.zeros((cap_rows,), bool)
        self.alive[:n] = True

        self.n_rows = n                 # row-store high-water mark
        self.next_gid = n
        self.n_tombstones = 0
        self.generation = 0             # bumped on every mutation
        self._n_base = n                # rows ≥ _n_base are delta (graph)
        self._graph: np.ndarray | None = None   # lazily-built adjacency
        self._graph_degree = 16
        self._gid_row: dict[int, int] = {i: i for i in range(n)}
        self._assignment: np.ndarray | None = None   # list → shard
        self._n_shards: int | None = None
        self._dev_cache: dict | None = None
        self._snap_cache: tuple[int, FaTRQIndex, np.ndarray] | None = None
        self._ex_cache: dict = {}
        self._gen_hooks: list = []

    # ------------------------------------------------------------ stats

    @property
    def cap_rows(self) -> int:
        return int(self.x.shape[0])

    @property
    def n_live(self) -> int:
        return len(self._gid_row)

    @property
    def n_delta_rows(self) -> int:
        return int(self.delta_len.sum())

    def __len__(self) -> int:
        return self.n_live

    def stats(self) -> dict:
        live, tomb = self.n_live, self.n_tombstones
        return {"n_live": live, "n_rows": self.n_rows,
                "cap_rows": self.cap_rows, "n_delta_rows": self.n_delta_rows,
                "n_tombstones": tomb, "generation": self.generation,
                **self.drift()}

    def drift(self) -> dict:
        """The rebalance-trigger metrics (see ``needs_compaction``).

        ``shard_imbalance`` is the stale assignment's heaviest shard load
        over the heaviest load a FRESH ``lpt_assign`` on the current
        effective list lengths would achieve — i.e. the factor
        ``rebalance()`` could actually shrink it by.  Comparing against a
        lower bound on OPT instead would mis-trigger on workloads no
        partition can balance (few near-equal lists), spinning
        ``auto_compact`` on every mutation.
        """
        live, tomb = self.n_live, self.n_tombstones
        d = {"tombstone_frac": tomb / max(live + tomb, 1),
             "delta_frac": self.n_delta_rows / max(live, 1)}
        if self._assignment is not None:
            s = self._n_shards
            lens_eff = (self.base_len + self.delta_len).astype(np.int64)
            loads = np.bincount(self._assignment, weights=lens_eff,
                                minlength=s)
            _, fresh = lpt_assign(lens_eff, s)
            d["shard_imbalance"] = float(loads.max()) / max(
                float(fresh.max()), 1.0)
            d["lpt_bound"] = 4.0 / 3.0 - 1.0 / (3.0 * s)
        return d

    def needs_compaction(self) -> bool:
        """True once any drift metric crosses its threshold: tombstone
        fraction, delta fraction, or (with a live shard assignment) the
        heaviest shard exceeding what a fresh LPT partition would achieve
        by more than the LPT (4/3 − 1/3S) guarantee factor."""
        if self.n_live == 0:
            return False                    # nothing to fold or balance
        d = self.drift()
        if d["tombstone_frac"] > self.scfg.max_tombstone_frac:
            return True
        if d["delta_frac"] > self.scfg.max_delta_frac:
            return True
        if "shard_imbalance" in d and d["shard_imbalance"] > d["lpt_bound"]:
            return True
        return False

    # ---------------------------------------------------------- mutation

    def add_generation_hook(self, fn) -> None:
        """Register ``fn(index, generation)`` to fire after EVERY mutation
        that bumps the generation (``insert``/``delete``/``compact``/
        ``rebalance``).  Observers that key state on the generation — the
        serving layer's query-result cache (``serving.cache.ResultCache``)
        is the canonical one — use this to invalidate proactively instead
        of holding stale entries until their keys age out."""
        self._gen_hooks.append(fn)

    def _invalidate(self) -> None:
        self.generation += 1
        self._dev_cache = None
        self._snap_cache = None
        for fn in list(self._gen_hooks):
            fn(self, self.generation)

    def _observe_mutation(self, op: str, **attrs) -> None:
        """Mutation observability: always-on cheap metrics (mutation
        counter by op + tombstone/delta drift gauges), and — only when a
        tracer is active — an ``index.<op>`` event carrying the FULL
        drift picture (``drift()`` re-runs ``lpt_assign`` under a live
        shard assignment, too expensive for the untraced path)."""
        reg = obs_metrics.active()
        reg.counter("streaming_mutations_total", "index mutations by op",
                    labelnames=("op",)).labels(op=op).inc()
        live, tomb = self.n_live, self.n_tombstones
        reg.gauge("streaming_tombstone_frac",
                  "tombstoned fraction of tracked rows").set(
                      tomb / max(live + tomb, 1))
        reg.gauge("streaming_delta_frac",
                  "delta-page rows over live rows").set(
                      self.n_delta_rows / max(live, 1))
        if trace.active() is not None:
            payload = {"generation": self.generation, "n_live": live,
                       **self.drift()}
            payload.update(attrs)
            trace.event(f"index.{op}", track="index", **payload)

    def _grow_rows(self, need: int) -> None:
        new_cap = max(need, 2 * self.cap_rows)
        self.pq_codes = _pad_rows(self.pq_codes, new_cap)
        self.trq = trq_mod.TRQCodes(
            dim=self.trq.dim,
            levels=tuple(jax.tree.map(lambda a: _pad_rows(a, new_cap), lv)
                         for lv in self.trq.levels),
            scalars=jax.tree.map(lambda a: _pad_rows(a, new_cap),
                                 self.trq.scalars),
            model=self.trq.model)
        self.x = _pad_rows(self.x, new_cap)
        self.row_gid = np.concatenate(
            [self.row_gid, np.full(new_cap - len(self.row_gid), -1,
                                   np.int64)])
        self.alive = np.concatenate(
            [self.alive, np.zeros(new_cap - len(self.alive), bool)])

    def insert(self, x_new: jax.Array) -> np.ndarray:
        """Append a batch of vectors; returns their global ids.

        Assign to the nearest (frozen) centroid, PQ- and TRQ-encode ONLY
        the new rows, append them to the row store, and push their row ids
        onto the owning lists' delta pages (bucketized scatter, no Python
        loop).  O(batch) encode + append work — existing rows untouched.
        """
        x_new = jnp.asarray(x_new, jnp.float32)
        if x_new.ndim == 1:
            x_new = x_new[None]
        b = int(x_new.shape[0])
        if b == 0:
            return np.zeros((0,), np.int64)
        if self.n_rows + b > self.cap_rows:
            self._grow_rows(self.n_rows + b)

        list_ids = np.asarray(assign(x_new, self.centroids))
        pq = pq_mod.encode(self.codebook, x_new)
        x_c = pq_mod.decode(self.codebook, pq)
        new_trq = trq_mod.encode_rows(x_new, x_c,
                                      num_levels=self.config.trq_levels,
                                      model=self.trq.model)
        start = self.n_rows
        self.pq_codes = jax.lax.dynamic_update_slice(self.pq_codes, pq,
                                                     (start, 0))
        self.trq = trq_mod.write_rows(self.trq, new_trq, start)
        self.x = jax.lax.dynamic_update_slice(
            self.x, x_new.astype(self.x.dtype), (start, 0))

        rows = np.arange(start, start + b)
        gids = np.arange(self.next_gid, self.next_gid + b)
        self.row_gid[rows] = gids
        self.alive[rows] = True
        self._gid_row.update(zip(gids.tolist(), rows.tolist()))
        self.n_rows += b
        self.next_gid += b

        # online graph maintenance: wire the new rows into the adjacency
        # (only once a graph search has materialized it)
        if self._graph is not None:
            self._graph = graph_mod.insert_nodes(
                self._graph, np.asarray(self.x[: self.n_rows]), start)

        # delta append: bucketize the batch by list, grow pages if needed
        counts = np.bincount(list_ids, minlength=self.nlist).astype(np.int32)
        need = int((self.delta_len + counts).max())
        dcap = self.delta_lists.shape[1]
        if need > dcap:
            page = self.scfg.delta_page
            new_dcap = ((need + page - 1) // page) * page
            self.delta_lists = np.concatenate(
                [self.delta_lists,
                 np.full((self.nlist, new_dcap - dcap), -1, np.int32)],
                axis=1)
        order = np.argsort(list_ids, kind="stable")
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        pos = (np.arange(b) - starts[list_ids[order]]
               + self.delta_len[list_ids[order]])
        self.delta_lists[list_ids[order], pos] = rows[order]
        self.delta_len += counts

        self._invalidate()
        self._observe_mutation("insert", n=b)
        if self.scfg.auto_compact:
            self.maybe_compact()
        return gids

    def delete(self, gids) -> int:
        """Tombstone the given global ids (masked out of search until the
        next compaction).  Raises KeyError on unknown/already-deleted/
        duplicated ids BEFORE mutating anything, so a bad batch leaves the
        index untouched; returns the number of tombstones set."""
        gids = np.atleast_1d(np.asarray(gids, np.int64)).tolist()
        if len(set(gids)) != len(gids):
            raise KeyError(f"duplicate ids in delete batch of {len(gids)}")
        rows = [self._gid_row[g] for g in gids]   # KeyError: unknown id
        for g, row in zip(gids, rows):
            del self._gid_row[g]
            self.alive[row] = False
        self.n_tombstones += len(gids)
        self._invalidate()
        self._observe_mutation("delete", n=len(gids))
        if self.scfg.auto_compact:
            self.maybe_compact()
        return len(gids)

    # ------------------------------------------------- compact / rebalance

    def _live_assignment(self) -> tuple[np.ndarray, np.ndarray]:
        """(live rows in stable order, their list ids) — assignment
        recomputed from scratch against the frozen centroids, exactly what
        a static rebuild on the surviving rows would do (``assign`` is
        row-independent, so this also equals the tracked membership)."""
        live_rows = np.where(self.alive[: self.n_rows])[0]
        if live_rows.size == 0:
            raise ValueError("empty index: nothing to compact/search")
        list_ids = np.asarray(assign(self.x[jnp.asarray(live_rows)],
                                     self.centroids))
        return live_rows, list_ids

    def compact(self) -> dict:
        """Fold delta pages into base lists and drop tombstones.

        One gather repacks the row store to the surviving rows (stable
        order — global ids stay monotonic in row order); base lists are
        refilled with the vectorized ``ivf.fill_lists``; delta pages reset
        to one empty page.  No re-encode: TRQ codes are centroid-relative
        and move with their rows.
        """
        folded, dropped = self.n_delta_rows, self.n_tombstones
        x_old = np.asarray(self.x[: self.n_rows]) \
            if self._graph is not None else None
        live_rows, list_ids = self._live_assignment()
        n_live = live_rows.size
        cap = int(3.0 * n_live / self.nlist) + 1
        lists, lens, _ = ivf_mod.fill_lists(list_ids, self.nlist, cap)

        perm = jnp.asarray(live_rows)
        new_cap = int(n_live * (1.0 + self.scfg.row_headroom)) + 1
        self.pq_codes = _pad_rows(self.pq_codes[perm], new_cap)
        self.trq = trq_mod.TRQCodes(
            dim=self.trq.dim,
            levels=tuple(jax.tree.map(lambda a: _pad_rows(a[perm], new_cap),
                                      lv) for lv in self.trq.levels),
            scalars=jax.tree.map(lambda a: _pad_rows(a[perm], new_cap),
                                 self.trq.scalars),
            model=self.trq.model)
        self.x = _pad_rows(self.x[perm], new_cap)

        gids = self.row_gid[live_rows]
        self.row_gid = np.full((new_cap,), -1, np.int64)
        self.row_gid[:n_live] = gids
        self.alive = np.zeros((new_cap,), bool)
        self.alive[:n_live] = True
        self._gid_row = dict(zip(gids.tolist(), range(n_live)))

        self.base_lists, self.base_len = lists, lens
        self.delta_lists = np.full((self.nlist, self.scfg.delta_page), -1,
                                   np.int32)
        self.delta_len = np.zeros((self.nlist,), np.int32)
        self.n_rows = n_live
        self.n_tombstones = 0
        # graph maintenance: drop dead rows, patch edges through them with
        # the one-hop contraction; all surviving rows are base again
        if self._graph is not None:
            self._graph = graph_mod.compact_graph(self._graph, x_old,
                                                  live_rows)
        self._n_base = n_live
        self._invalidate()
        self._observe_mutation("compact", folded_delta_rows=folded,
                               dropped_tombstones=dropped)
        return {"folded_delta_rows": folded, "dropped_tombstones": dropped,
                "n_live": n_live}

    def rebalance(self, n_shards: int) -> dict:
        """Compact, then re-partition lists across ``n_shards`` with the
        same LPT greedy the static partitioner uses.  Reports how many
        rows MOVED shards relative to the previous assignment — a move is
        a gather of already-encoded packed codes (no re-encode)."""
        prev = self._assignment
        stats = self.compact()
        members, _ = lpt_assign(self.base_len, n_shards)
        assignment = np.empty((self.nlist,), np.int32)
        for s, m in enumerate(members):
            assignment[m] = s
        if prev is not None and self._n_shards == n_shards:
            moved_lists = np.nonzero(assignment != prev)[0]
            stats["moved_rows"] = int(self.base_len[moved_lists].sum())
        else:
            stats["moved_rows"] = int(self.base_len.sum())
        self._assignment = assignment
        self._n_shards = n_shards
        stats["shard_loads"] = [int(self.base_len[m].sum()) for m in members]
        self._invalidate()
        self._observe_mutation("rebalance", moved_rows=stats["moved_rows"],
                               shard_loads=stats["shard_loads"])
        return stats

    def maybe_compact(self) -> dict | None:
        """Drift-triggered fold: ``rebalance`` when a shard assignment is
        live, else ``compact``.  No-op (None) below the thresholds."""
        if not self.needs_compaction():
            return None
        if self._n_shards is not None:
            return self.rebalance(self._n_shards)
        return self.compact()

    # ----------------------------------------------------------- snapshot

    def rebuild_static(self) -> tuple[FaTRQIndex, np.ndarray]:
        """From-scratch static rebuild on the surviving rows.

        Reassigns every survivor into fresh inverted lists against the
        trained quantizers and gathers a dense row store — a plain
        ``FaTRQIndex`` (rebuilding the quantizers themselves on drifted
        data is a model update, out of index-maintenance scope).  Returns
        (index, gid) with ``gid[i]`` the global id of the static index's
        row ``i``; ``StreamingIndex.search`` matches its top-k exactly
        (see module docstring).  Cached per generation — also the
        snapshot behind ``search(shards=...)``.
        """
        if self._snap_cache is not None \
                and self._snap_cache[0] == self.generation:
            return self._snap_cache[1], self._snap_cache[2]
        live_rows, list_ids = self._live_assignment()
        cap = int(3.0 * live_rows.size / self.nlist) + 1
        lists, lens, _ = ivf_mod.fill_lists(list_ids, self.nlist, cap)
        perm = jnp.asarray(live_rows)
        idx = FaTRQIndex(
            config=self.config, codebook=self.codebook,
            pq_codes=self.pq_codes[perm],
            ivf=ivf_mod.IVFIndex(centroids=self.centroids,
                                 lists=jnp.asarray(lists),
                                 list_len=jnp.asarray(lens)),
            trq=trq_mod.gather_rows(self.trq, perm),
            x=self.x[perm])
        gid = self.row_gid[live_rows].copy()
        self._snap_cache = (self.generation, idx, gid)
        return idx, gid

    # ------------------------------------------------------------- search

    def _graph_host(self) -> np.ndarray:
        """The online-maintained adjacency over rows ``0..n_rows`` —
        including tombstoned rows (traversal routes through them until the
        next compaction).  Built once from the current row store on first
        graph search; ``insert``/``compact`` keep it wired incrementally
        from then on (never rebuilt)."""
        if self._graph is None:
            self._graph = np.asarray(graph_mod.build(
                self.x[: self.n_rows], degree=self._graph_degree).neighbors)
        return self._graph

    def _dev(self) -> dict:
        if self._dev_cache is None or \
                self._dev_cache["gen"] != self.generation:
            self._dev_cache = {
                "gen": self.generation,
                "base_lists": jnp.asarray(self.base_lists),
                "delta_lists": jnp.asarray(self.delta_lists),
                "alive": jnp.asarray(self.alive),
                "row_gid": jnp.asarray(self.row_gid),
            }
        return self._dev_cache

    def execute(self, queries: jax.Array, *, k: int | None = None,
                front: str | None = None, backend: str | None = None,
                micro_batch: int | None = None,
                refine_budget: int | None = None,
                cost: QueryCost | None = None, shards: int | None = None
                ) -> tuple[jax.Array, jax.Array, QueryCost]:
        """Generation-aware FaTRQ search → (Q, k) GLOBAL ids, (Q, k) exact
        squared-L2 distances, and the traffic ledger.

        The IVF front probes base ∪ delta lists and masks tombstones; the
        graph front beam-searches the online-maintained adjacency with the
        same masking.  Both refine backends score base and delta rows under
        one QueryCost (delta traffic on its own ``delta:cxl`` entry).
        ``shards`` routes a static snapshot through ``anns.sharding`` (with
        the requested front) and maps the results back to global ids.
        """
        cfg = self.config
        k = k or cfg.final_k
        front = front or "ivf"
        backend = backend or cfg.backend
        micro_batch = micro_batch if micro_batch is not None \
            else cfg.micro_batch

        if shards is not None:
            from repro.anns.sharding import make_sharded_executor
            idx, gid = self.rebuild_static()
            sx = make_sharded_executor(idx, shards=shards, front=front,
                                       backend=backend,
                                       micro_batch=micro_batch,
                                       refine_budget=refine_budget)
            ids, dists, scost = sx.execute(queries, k=k, cost=cost)
            return jnp.asarray(gid)[ids], dists, scost

        dev = self._dev()
        ex = self._executor(front, backend, micro_batch, dev,
                            refine_budget=refine_budget)
        rows, dists, out_cost = ex.execute(queries, k=k, cost=cost)
        return dev["row_gid"][rows], dists, out_cost

    def search(self, queries: jax.Array, *, k: int | None = None,
               front: str | None = None, backend: str | None = None,
               micro_batch: int | None = None,
               cost: QueryCost | None = None, shards: int | None = None
               ) -> tuple[jax.Array, QueryCost]:
        """Legacy tuple surface over ``execute`` (no distances)."""
        ids, _, out_cost = self.execute(queries, k=k, front=front,
                                        backend=backend,
                                        micro_batch=micro_batch, cost=cost,
                                        shards=shards)
        return ids, out_cost

    def _executor(self, front: str, backend: str, micro_batch: int | None,
                  dev: dict,
                  refine_budget: int | None = None) -> SearchExecutor:
        """Plain ``SearchExecutor`` over the current generation — the
        streaming fronts satisfy the ``FrontStage`` protocol and
        ``StreamingIndex`` quacks like a ``FaTRQIndex`` (``config``,
        ``layout``, ``trq``, ``x``), so search/fold logic lives in ONE
        place.  Front and backend come from the capability registry
        (``anns.registry``); cached per (generation, front, backend,
        micro_batch, refine_budget)."""
        key = (dev["gen"], front, backend, micro_batch, refine_budget)
        ex = self._ex_cache.get(key)
        if ex is not None:
            return ex
        be = registry.make_backend(backend)
        fs = registry.make_front(front, "streaming", self)
        ex = SearchExecutor(index=self, front=fs, backend=be,
                            micro_batch=micro_batch,
                            refine_budget=refine_budget)
        # keep only the current generation's executors (stale fronts hold
        # references to superseded device arrays)
        self._ex_cache = {kk: v for kk, v in self._ex_cache.items()
                          if kk[0] == dev["gen"]}
        self._ex_cache[key] = ex
        return ex


# ----------------------------------------------------- registry integration
# Both fronts declare streaming support in ``anns.stages``; the factories
# building their generation-aware physical variants live here, next to the
# stages.


def make_streaming_front(st: StreamingIndex, **opts) -> StreamingFrontStage:
    nprobe = opts.pop("nprobe", st.config.nprobe)
    if opts:
        raise TypeError(f"unknown streaming front options: {sorted(opts)}")
    dev = st._dev()
    return StreamingFrontStage(
        centroids=st.centroids, codebook=st.codebook, pq_codes=st.pq_codes,
        base_lists=dev["base_lists"], delta_lists=dev["delta_lists"],
        alive=dev["alive"], nprobe=nprobe)


def make_streaming_graph_front(st: StreamingIndex,
                               **opts) -> GraphStreamingFrontStage:
    """Materialize (or reuse) the online-maintained adjacency and bind the
    current generation's alive bitmap + delta boundary to the stage."""
    degree = opts.pop("degree", st._graph_degree)
    if degree != st._graph_degree and st._graph is not None:
        raise ValueError(f"streaming graph was materialized at degree "
                         f"{st._graph_degree}, cannot serve degree {degree}")
    st._graph_degree = degree
    nb = st._graph_host()
    return GraphStreamingFrontStage(
        graph=graph_mod.GraphIndex(neighbors=jnp.asarray(nb)),
        codebook=st.codebook, pq_codes=st.pq_codes[: st.n_rows],
        alive=jnp.asarray(st.alive[: st.n_rows]), n_base=st._n_base, **opts)


registry.add_front_factory("ivf", "streaming", make_streaming_front)
registry.add_front_factory("graph", "streaming", make_streaming_graph_front)
