"""Unified Database handle + QueryPlan capability layer.

One query API over every physical index layout.  After the sharded and
streaming subsystems landed, the entry points had forked: ``pipeline.search``
and ``serving.Retriever`` each hand-rolled ``isinstance(StreamingIndex)``
checks, ``shards``-vs-unsharded branches, per-call executor construction,
and triplicated "IVF front only" error strings.  This module is the seam
that replaces all of that — the same "one logical index, many physical
layouts" shape COSMOS and AiSAQ expose over their CXL / all-in-storage
backends:

* ``Database`` — a uniform handle over ``FaTRQIndex`` (static),
  ``ShardedIndex`` (mesh-partitioned), ``StreamingIndex`` (mutable) and
  ``TieredIndex`` (heat-driven hot/warm/cold placement).
  ``Database.build(key, x, config)`` builds a static index;
  ``Database.wrap(index)`` adopts an existing one (cached on the index
  instance, so facade callers share one handle and its executor cache).

* ``QueryPlan`` — a frozen description of HOW to search: front stage,
  refine backend, shard count, k, SSD refine budget, query micro-batch.
  ``None`` fields resolve from the index config; the resolved plan is
  **validated once** against the capability registry (``anns.registry``)
  — every front stage / refine backend declares the layouts it supports —
  and **compiled once** into an executor cached per
  ``(index generation, plan, mesh)``.  Unsupported combinations raise
  ``PlanError`` at plan time, never mid-search.

* ``SearchResult`` — structured output: top-k ids, the exact squared-L2
  distances of those ids (previously computed in every rerank and dropped
  on the floor), the ``QueryCost`` traffic ledger, and the resolved plan
  that produced them (so benchmark records are attributable).

Executor-cache keying: the *generation* of a static/sharded index is
always 0 (immutable); a ``StreamingIndex`` bumps its generation on every
``insert``/``delete``/``compact``/``rebalance``, so a cached executor —
including the sharded snapshot behind ``shards=S`` — is invalidated
exactly when the physical layout changes.  Stale-generation entries are
pruned so superseded device arrays are not pinned.

``pipeline.search`` / ``baseline_search`` / ``serving.Retriever`` are thin
shims over this module, bit-identical to their pre-refactor behavior.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.anns import registry
from repro.anns.executor import make_executor, search_budget
from repro.anns.pipeline import FaTRQIndex, PipelineConfig
from repro.anns.pipeline import build as _build_index
from repro.anns.registry import PlanError
from repro.anns.sharding import ShardedExecutor, ShardedIndex, \
    make_sharded_executor
from repro.anns.streaming import StreamingIndex
from repro.anns.tiered import TieredIndex
from repro.memory import QueryCost
from repro.obs import trace

__all__ = ["CompiledPlan", "Database", "QueryPlan", "SearchResult",
           "PlanError"]


@dataclass(frozen=True)
class QueryPlan:
    """How to run a search.  ``None`` fields resolve from the index config
    (``resolve``); a fully-resolved plan is hashable and keys the
    compiled-executor cache.  ``mode="baseline"`` selects the no-refinement
    comparison path (coarse ADC + full SSD rerank), static layout only."""

    front: str | None = None          # "ivf" | "graph" | any registered
    backend: str | None = None        # "reference" | "pallas"
    shards: int | None = None         # None = unsharded; S ≥ 1 = mesh shards
    k: int | None = None              # top-k; None → config.final_k
    refine_budget: int | None = None  # max SSD fetches; None → config's
    micro_batch: int | None = None    # queries/device step; None → config's
    mode: str = "fatrq"               # "fatrq" | "baseline"

    def resolve(self, config: PipelineConfig) -> "QueryPlan":
        """Fill every ``None`` field from ``config`` (budget via the shared
        ``executor.search_budget`` derivation, so plan-carrying paths stay
        bit-identical to config-driven ones)."""
        k = self.k or config.final_k
        return dataclasses.replace(
            self,
            front=self.front or config.front,
            backend=self.backend or config.backend,
            k=k,
            refine_budget=search_budget(config, k, self.refine_budget),
            micro_batch=self.micro_batch if self.micro_batch is not None
            else config.micro_batch)

    def to_record(self) -> dict:
        """JSON-friendly dict (benchmark records, logs)."""
        return dataclasses.asdict(self)


@dataclass(frozen=True)
class SearchResult:
    """Structured search output.

    ``distances`` are the exact squared-L2 distances of ``ids`` computed by
    the SSD rerank stage (+inf on padded slots when fewer than k candidates
    survived); ``plan`` is the fully-resolved ``QueryPlan`` that produced
    the result.
    """

    ids: jax.Array          # (Q, k) int32 — global database ids
    distances: jax.Array    # (Q, k) f32 — exact squared L2 of ``ids``
    cost: QueryCost         # the Table-I traffic ledger
    plan: QueryPlan         # resolved plan (fully specified, hashable)


@dataclass
class CompiledPlan:
    """A validated plan bound to its compiled executor at one index
    generation — the serving engine's dispatch handle.

    ``Database.compiled(plan)`` resolves + validates once and returns this
    wrapper; calling it again after a ``StreamingIndex`` mutation returns
    a fresh handle for the new generation (the underlying executor cache
    is generation-keyed).  ``run_front``/``run_finish`` expose the staged
    executor's front/refine boundary for double-buffered dispatch — the
    two calls together are exactly ``execute`` on one micro-batch, so
    split dispatch stays bit-identical to ``db.query``.  Layouts without
    a split surface (the sharded shard_map body fuses both stages in one
    launch) report ``supports_split == False``; dispatch whole batches
    through ``execute`` there.
    """

    db: "Database"
    plan: QueryPlan          # fully resolved
    generation: int          # index generation at compile time
    _ex: object
    _gid: jax.Array | None   # row → global id postmap (streaming layouts)

    @property
    def supports_split(self) -> bool:
        return hasattr(self._ex, "run_front")

    def execute(self, queries: jax.Array, *, pad: bool = False,
                cost: QueryCost | None = None) -> SearchResult:
        """Whole-batch dispatch (front + refine + rerank + fold)."""
        if self.plan.mode == "baseline":
            ids, dists, out = self._ex.execute_baseline(
                queries, k=self.plan.k, pad=pad)
            if cost is not None:
                out = cost.merge(out)
        else:
            ids, dists, out = self._ex.execute(queries, k=self.plan.k,
                                               cost=cost, pad=pad)
        if self._gid is not None:
            ids = self._gid[ids]
        return SearchResult(ids=ids, distances=dists, cost=out,
                            plan=self.plan)

    def run_front(self, chunk: jax.Array, *,
                  qvalid: jax.Array | None = None):
        """Stage 1: candidate generation for ONE micro-batch (≤ the
        plan's ``micro_batch``); returns the device-side ``Candidates``
        handle to pass to ``run_finish``."""
        return self._ex.run_front(chunk, qvalid=qvalid)

    def run_finish(self, chunk: jax.Array, cand, *,
                   cost: QueryCost | None = None) -> SearchResult:
        """Stage 2: refine + rerank + ledger fold for a ``run_front``
        result, mapped to global ids."""
        ids, dists, out = self._ex.run_finish(chunk, cand, k=self.plan.k,
                                              cost=cost)
        if self._gid is not None:
            ids = self._gid[ids]
        return SearchResult(ids=ids, distances=dists, cost=out,
                            plan=self.plan)


def _layout_of(index) -> str:
    if isinstance(index, TieredIndex):
        return "tiered"
    if isinstance(index, StreamingIndex):
        return "streaming"
    if isinstance(index, ShardedIndex):
        return "sharded"
    if isinstance(index, FaTRQIndex):
        return "static"
    raise TypeError(f"cannot wrap {type(index).__name__}: expected "
                    f"FaTRQIndex, ShardedIndex, StreamingIndex or "
                    f"TieredIndex")


class Database:
    """Uniform query handle over one logical index in any physical layout.

    ``query`` is the single entry point: resolve the plan against the
    index config, validate it against the capability registry (raising
    ``PlanError`` on unsupported combinations BEFORE any device work),
    compile-or-fetch the executor for ``(generation, plan, mesh)``, run
    it, and return a ``SearchResult``.
    """

    def __init__(self, index, *, layout: str | None = None):
        self.index = index
        self.layout = layout or _layout_of(index)
        self._compiled: dict[tuple, tuple] = {}

    # -- construction -----------------------------------------------------

    @classmethod
    def build(cls, key: jax.Array, x: jax.Array,
              config: PipelineConfig) -> "Database":
        """Offline build (PQ → IVF → TRQ encode → calibration) wrapped in a
        fresh handle."""
        return cls.wrap(_build_index(key, x, config))

    @classmethod
    def wrap(cls, index) -> "Database":
        """Adopt an existing index.  The handle is cached ON the index
        instance so every wrap of the same index shares one executor
        cache (facade callers create handles per call)."""
        if isinstance(index, Database):
            return index
        db = getattr(index, "_db_handle", None)
        if db is None:
            db = cls(index)
            index._db_handle = db
        return db

    # -- introspection ----------------------------------------------------

    @property
    def config(self) -> PipelineConfig:
        return self.index.config

    @property
    def generation(self) -> int:
        """Physical-layout generation: 0 for immutable layouts; the
        mutation counter for a ``StreamingIndex``."""
        return getattr(self.index, "generation", 0)

    def __len__(self) -> int:
        if self.layout == "streaming":
            return self.index.n_live
        if self.layout == "sharded":
            return int(self.index.shard_rows.sum())
        return int(self.index.x.shape[0])

    # -- planning ---------------------------------------------------------

    def _effective_layout(self, plan: QueryPlan) -> str:
        """The physical datapath a plan selects: a shard count on a static
        index routes through the sharded subsystem; a streaming index stays
        streaming (``shards`` there searches a static snapshot, but the
        capability constraint is the streaming front's)."""
        if self.layout != "static":
            return self.layout
        return "sharded" if plan.shards is not None else "static"

    def validate(self, plan: QueryPlan | None = None) -> QueryPlan:
        """Resolve ``plan`` against the index config and validate the
        (front, backend, layout) combination against the capability
        registry.  Returns the resolved plan; raises ``PlanError`` on any
        unsupported combination or unknown name — this is the plan-time
        choke point, nothing below it re-checks."""
        p = (plan or QueryPlan()).resolve(self.config)
        layout = self._effective_layout(p)
        registry.validate_combo(p.front, p.backend, layout)
        if self.layout == "streaming" and p.shards is not None:
            # the snapshot behind shards=S runs the sharded datapath too
            registry.validate_combo(p.front, p.backend, "sharded")
        if p.mode == "baseline":
            if layout != "static":
                raise PlanError(
                    f"unsupported plan: mode 'baseline' cannot run on the "
                    f"{layout!r} index layout — the no-refinement baseline "
                    f"supports layouts [static] only")
        elif p.mode != "fatrq":
            raise PlanError(f"unknown search mode {p.mode!r}; expected "
                            f"'fatrq' or 'baseline'")
        if self.layout == "tiered" and p.shards is not None:
            raise PlanError(
                f"unsupported plan: shards={p.shards} cannot run on the "
                f"'tiered' index layout — heat-driven placement is "
                f"per-device; partition the wrapped static index "
                f"(Database.wrap(tiered.inner)) and re-apply tiering per "
                f"shard instead")
        if self.layout == "sharded":
            if p.shards not in (None, self.index.n_shards):
                raise PlanError(
                    f"plan asks for {p.shards} shards but the wrapped "
                    f"ShardedIndex is partitioned {self.index.n_shards} "
                    f"ways — re-partition the base index instead")
            if p.front != self.index.front:
                raise PlanError(
                    f"plan asks for the {p.front!r} front but the wrapped "
                    f"ShardedIndex was partitioned for the "
                    f"{self.index.front!r} front (IVF shards whole lists, "
                    f"graph shards vector ranges + halo) — re-partition "
                    f"the base index for {p.front!r} instead")
        return p

    # -- compilation ------------------------------------------------------

    def executor_for(self, plan: QueryPlan, *, mesh=None):
        """Validate + compile ``plan`` into its executor (cached per
        ``(generation, resolved plan, mesh)``).  Returns the executor; the
        global-id postmap (streaming layouts) stays internal."""
        rp = self.validate(plan)
        return self._compile(rp, mesh)[0]

    def compiled(self, plan: QueryPlan | None = None, *,
                 mesh=None) -> CompiledPlan:
        """Validate + compile ``plan`` and return the ``CompiledPlan``
        dispatch handle (executor + global-id postmap + the generation it
        was compiled against).  The serving engine calls this per batch:
        cache hits make it O(1), and a streaming generation bump
        transparently recompiles."""
        rp = self.validate(plan)
        ex, gid_map = self._compile(rp, mesh)
        return CompiledPlan(db=self, plan=rp, generation=self.generation,
                            _ex=ex, _gid=gid_map)

    def _compile(self, rp: QueryPlan, mesh=None) -> tuple:
        """Resolved+validated plan → (executor, gid postmap | None).

        Underlying factories (``make_executor`` / ``make_sharded_executor``
        / ``StreamingIndex._executor``) memoize on the index, so stale-
        generation pruning here never redoes partitioning or stage builds
        that are still current."""
        gen = self.generation
        key = (gen, rp, mesh)
        hit = self._compiled.get(key)
        trace.event("plan.compile", track="query", cache_hit=hit is not None,
                    generation=gen, layout=self.layout)
        if hit is not None:
            return hit
        # prune executors compiled against superseded generations (their
        # fronts pin replaced device arrays)
        self._compiled = {kk: v for kk, v in self._compiled.items()
                          if kk[0] == gen}
        with trace.span("plan.compile.build", track="query",
                        layout=self.layout, generation=gen):
            entry = self._build(rp, mesh)
        self._compiled[key] = entry
        return entry

    def _build(self, rp: QueryPlan, mesh) -> tuple:
        """Compile-miss path of ``_compile``: construct the executor (and
        gid postmap) for a resolved plan."""
        if self.layout == "streaming":
            st: StreamingIndex = self.index
            if rp.shards is not None:
                idx, gid = st.rebuild_static()
                ex = make_sharded_executor(
                    idx, shards=rp.shards, front=rp.front,
                    backend=rp.backend, micro_batch=rp.micro_batch,
                    refine_budget=rp.refine_budget, mesh=mesh)
                entry = (ex, jnp.asarray(gid))
            else:
                dev = st._dev()
                ex = st._executor(rp.front, rp.backend, rp.micro_batch,
                                  dev, refine_budget=rp.refine_budget)
                entry = (ex, dev["row_gid"])
        elif self.layout == "sharded":
            ex = ShardedExecutor(sharded=self.index, backend=rp.backend,
                                 micro_batch=rp.micro_batch,
                                 refine_budget=rp.refine_budget)
            entry = (ex, None)
        elif self.layout == "tiered":
            ex = make_executor(self.index, front=rp.front,
                               backend=rp.backend,
                               micro_batch=rp.micro_batch,
                               refine_budget=rp.refine_budget,
                               layout="tiered")
            entry = (ex, None)
        elif rp.shards is not None:
            ex = make_sharded_executor(
                self.index, shards=rp.shards, front=rp.front,
                backend=rp.backend, micro_batch=rp.micro_batch,
                refine_budget=rp.refine_budget, mesh=mesh)
            entry = (ex, None)
        else:
            ex = make_executor(self.index, front=rp.front,
                               backend=rp.backend,
                               micro_batch=rp.micro_batch,
                               refine_budget=rp.refine_budget)
            entry = (ex, None)
        return entry

    # -- querying ---------------------------------------------------------

    def query(self, queries: jax.Array, *, plan: QueryPlan | None = None,
              k: int | None = None, micro_batch: int | None = None,
              refine_budget: int | None = None, bucket: bool = False,
              cost: QueryCost | None = None, mesh=None) -> SearchResult:
        """Planned search → ``SearchResult``.

        ``k``, ``micro_batch`` and ``refine_budget`` are per-call
        overrides of the plan (a serving layer keeps one plan and varies
        k / batching / refine depth per request — per-tenant QoS maps
        token budgets onto ``refine_budget``).  A ``k`` override
        re-derives the SSD refine budget unless the plan's budget was
        pinned independently of its own k — otherwise reusing an
        already-resolved plan (e.g. ``result.plan``) with a larger k
        would silently keep the budget resolved for the OLD k and starve
        the rerank.  ``bucket=True`` pads ragged query chunks to
        power-of-two buckets (``executor.bucket_for``) so variable batch
        sizes reuse a fixed set of compiled shapes — results and ledger
        are bit-identical either way.  ``cost`` merges the call's traffic
        into an existing ledger, exactly like the executor surfaces it
        shims.
        """
        p = plan or QueryPlan()
        if k is not None:
            stale = p.k is not None and k != p.k and \
                p.refine_budget == search_budget(self.config, p.k)
            p = dataclasses.replace(
                p, k=k, refine_budget=None if stale else p.refine_budget)
        if refine_budget is not None:
            p = dataclasses.replace(p, refine_budget=refine_budget)
        if micro_batch is not None:
            p = dataclasses.replace(p, micro_batch=micro_batch)
        # attrs that touch ``queries`` are set only after validate: a bad
        # plan must raise PlanError before queries are ever inspected
        with trace.span("query", track="query", layout=self.layout) as sp_q:
            with trace.span("plan.resolve", track="query"):
                rp = self.validate(p)
            sp_q.set_attrs(plan=rp.to_record(),
                           n_queries=int(queries.shape[0]))
            ex, gid_map = self._compile(rp, mesh)
            if rp.mode == "baseline":
                ids, dists, out_cost = ex.execute_baseline(queries, k=rp.k,
                                                           pad=bucket)
                if cost is not None:
                    out_cost = cost.merge(out_cost)
            else:
                ids, dists, out_cost = ex.execute(queries, k=rp.k, cost=cost,
                                                  pad=bucket)
            if gid_map is not None:
                ids = gid_map[ids]
        return SearchResult(ids=ids, distances=dists, cost=out_cost,
                            plan=rp)
