"""Capability registry for the query-planning layer (anns/api.py).

One logical index, many physical layouts: a search request names a *front*
stage (candidate generation), a *refine backend* (FaTRQ estimation
datapath), and runs against an index *layout* ("static" ``FaTRQIndex``,
"sharded" ``ShardedIndex`` on a device mesh, "streaming"
``StreamingIndex`` with delta lists, "tiered" ``TieredIndex`` with
heat-driven hot/warm/cold placement).  The built-in matrix is CLOSED:
both fronts (IVF and graph) run on all four layouts — the graph front
gets a halo-partitioned sharded traversal from ``anns.sharding``, online
edge insertion from ``anns.streaming``/``index.graph``, and a
tier-annotating wrapper from ``anns.tiered``.  Before this layer each
entry point re-derived the support matrix with its own
``isinstance``/string if-chains and a triplicated "IVF front only" error
string.

Here every front stage and refine backend *declares* what it supports:

* ``register_front(name, layouts=..., make={layout: factory})`` — a front
  advertises the layouts it can run on and, per layout, a factory
  ``factory(index, **opts) -> FrontStage`` building the stage object for
  that physical layout (the sharded layout inlines its front inside the
  ``shard_map`` body, so it validates against the registry but constructs
  no stage object — it registers ``ShardedFrontHooks`` instead).
* ``register_backend(name, make=cls, layouts=...)`` — refine backends
  (today both run everywhere).
* ``add_front_factory(name, layout, factory)`` — a later-imported
  subsystem plugs its physical variant into an existing front (e.g.
  ``anns.streaming`` attaches the base ∪ delta IVF front and the
  tombstone-aware graph front).
* ``register_sharded_front(name, hooks)`` — a layout-pluggable
  partitioner + shard_map front body + ledger fold for the sharded
  datapath (``anns.sharding`` registers both built-ins: whole-list LPT
  for IVF, vector ranges + halo edges for graph).

``validate_combo`` is the single choke point: every unsupported pair
raises ``PlanError`` *at plan time* with a message naming the (front,
layout) pair, instead of a mid-search ``ValueError`` from whichever copy
of the dispatch ladder happened to notice first.  A new front×layout
combination stays a registry entry, not a fourth copy of the ladder.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

LAYOUTS = ("static", "sharded", "streaming", "tiered")


class PlanError(ValueError):
    """A QueryPlan names an unsupported (front, backend, layout)
    combination — raised at plan-validation time, never mid-search.
    Subclasses ``ValueError`` so pre-registry callers catching the old
    ad-hoc errors keep working."""


@dataclass(frozen=True)
class ShardedFrontHooks:
    """How a front runs on the sharded layout (see ``anns.sharding``):

    * ``partition(index, n_shards) -> (rows_per, rep, db, args)`` — split
      the database into per-shard row sets plus the front's own replicated
      (``rep``) and shard-stacked (``db``) array pytrees and a hashable
      tuple of static traversal args.
    * ``body(queries, rep, db, codebook, pq_codes, *, qvalid=None,
      **args) -> Candidates`` — the front's candidate generation inside
      the shard_map body (free to use collectives over the mesh axis,
      e.g. the graph front's per-hop frontier exchange).  ``qvalid`` is
      the replicated per-query validity mask of the bucket-padded entry
      (``executor.pad_chunk``): padded rows must yield no candidates and
      no counter contributions on any shard.
    * ``fold(cost, counts, layout)`` — the front's per-shard ledger fold.
    """

    partition: Callable
    body: Callable
    fold: Callable


@dataclass
class FrontSpec:
    """A registered front stage: supported layouts + per-layout factory."""

    name: str
    layouts: tuple[str, ...]
    factories: dict[str, Callable] = field(default_factory=dict)
    sharded: ShardedFrontHooks | None = None


@dataclass
class BackendSpec:
    """A registered refine backend: supported layouts + constructor."""

    name: str
    layouts: tuple[str, ...]
    make: Callable = None


_FRONTS: dict[str, FrontSpec] = {}
_BACKENDS: dict[str, BackendSpec] = {}


def register_front(name: str, *, layouts: tuple[str, ...],
                   make: dict[str, Callable] | None = None) -> None:
    """Declare a front stage and the index layouts it supports."""
    for lay in layouts:
        if lay not in LAYOUTS:
            raise ValueError(f"unknown layout {lay!r}; expected one of "
                             f"{LAYOUTS}")
    _FRONTS[name] = FrontSpec(name=name, layouts=tuple(layouts),
                              factories=dict(make or {}))


def register_backend(name: str, *, make: Callable,
                     layouts: tuple[str, ...] = LAYOUTS) -> None:
    """Declare a refine backend and the index layouts it supports."""
    _BACKENDS[name] = BackendSpec(name=name, layouts=tuple(layouts),
                                  make=make)


def add_front_factory(name: str, layout: str, factory: Callable) -> None:
    """Attach a physical-layout factory to an already-registered front
    (used by later-imported subsystems, e.g. the streaming IVF front)."""
    spec = front_spec(name)
    if layout not in spec.layouts:
        raise ValueError(f"front {name!r} does not declare layout "
                         f"{layout!r} (declared: {spec.layouts})")
    spec.factories[layout] = factory


def register_sharded_front(name: str, hooks: ShardedFrontHooks) -> None:
    """Attach the sharded-datapath hooks (partitioner + shard_map body +
    fold) to an already-registered front declaring the "sharded" layout."""
    spec = front_spec(name)
    if "sharded" not in spec.layouts:
        raise ValueError(f"front {name!r} does not declare layout "
                         f"'sharded' (declared: {spec.layouts})")
    spec.sharded = hooks


def sharded_front(name: str) -> ShardedFrontHooks:
    """The sharded-datapath hooks for ``name``.  A front declaring the
    sharded layout without registering hooks is a wiring bug, not a plan
    error."""
    spec = front_spec(name)
    if spec.sharded is None:
        raise KeyError(f"front {name!r} has no sharded-front hooks "
                       f"registered (declared layouts: {spec.layouts})")
    return spec.sharded


def front_names() -> tuple[str, ...]:
    return tuple(_FRONTS)


def backend_names() -> tuple[str, ...]:
    return tuple(_BACKENDS)


def front_spec(name: str) -> FrontSpec:
    try:
        return _FRONTS[name]
    except KeyError:
        raise PlanError(f"unknown front stage {name!r}; expected one of "
                        f"{tuple(_FRONTS)}") from None


def backend_spec(name: str) -> BackendSpec:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise PlanError(f"unknown refine backend {name!r}; expected one of "
                        f"{tuple(_BACKENDS)}") from None


def _pair_error(kind: str, name: str, supported: tuple[str, ...],
                layout: str) -> PlanError:
    """The capability-violation error, naming the unsupported pair and
    what WOULD work on each side of it (same-kind alternatives: a backend
    violation lists the backends the layout supports, not fronts)."""
    pool = _FRONTS if kind == "front" else _BACKENDS
    alts = sorted(n for n, s in pool.items() if layout in s.layouts)
    alt = "/".join(alts).upper() or "NO registered"
    return PlanError(
        f"unsupported plan: {kind} {name!r} cannot run on the {layout!r} "
        f"index layout — {kind} {name!r} supports layouts "
        f"[{', '.join(supported)}]; the {layout!r} layout supports the "
        f"{alt} {kind} only ({kind}s: {alts})")


def validate_combo(front: str, backend: str, layout: str) -> None:
    """Raise ``PlanError`` unless (front, backend) both support ``layout``.
    Unknown names raise too — validation happens once, at plan time."""
    if layout not in LAYOUTS:
        raise PlanError(f"unknown index layout {layout!r}; expected one of "
                        f"{LAYOUTS}")
    fs = front_spec(front)
    if layout not in fs.layouts:
        raise _pair_error("front", front, fs.layouts, layout)
    bs = backend_spec(backend)
    if layout not in bs.layouts:
        raise _pair_error("backend", backend, bs.layouts, layout)


def make_front(name: str, layout: str, index, **opts):
    """Build the front-stage object for (front, layout) via its registered
    factory.  The sharded layout registers no factory (its front is inlined
    in the shard_map body) — asking for one is a wiring bug, not a plan
    error."""
    spec = front_spec(name)
    if layout not in spec.layouts:
        raise _pair_error("front", name, spec.layouts, layout)
    factory = spec.factories.get(layout)
    if factory is None:
        raise KeyError(f"front {name!r} has no stage factory for layout "
                       f"{layout!r} (registered: "
                       f"{sorted(spec.factories)})")
    return factory(index, **opts)


def make_backend(name: str, **opts):
    """Build a refine-backend object via its registered constructor."""
    return backend_spec(name).make(**opts)
