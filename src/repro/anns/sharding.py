"""Sharded search subsystem: mesh-partitioned database + shard_map datapath.

Scale-out of the staged executor across devices (paper Fig. 6 scales
throughput by replicating the refinement datapath across far-memory
channels; COSMOS/HAVEN reach billion-scale by partitioning the candidate
datapath).  The partitioner and the in-shard front are LAYOUT-PLUGGABLE:
each front registers ``registry.ShardedFrontHooks`` — a partition scheme,
a shard_map front body, and a ledger fold — and everything downstream of
candidate generation (refine, rerank, merge, cost fold) is shared.

* ``partition_database(index, S, front=...)`` — dispatches to the front's
  partitioner:

  - **IVF** assigns WHOLE inverted lists to shards (a candidate's codes,
    scalars and full vector co-reside with its list), balanced by list
    length with an LPT greedy (heaviest list onto the lightest shard).
  - **graph** partitions the VECTORS into contiguous row ranges and gives
    each shard its subgraph plus HALO state: the adjacency of its owned
    rows (global ids and local slots) and the PQ-reconstruction vectors of
    every off-shard boundary neighbor, so a shard can expand any node it
    owns without touching another shard's memory mid-hop.

  Per-shard record arrays are stacked on a leading shard axis and row ids
  are re-indexed shard-locally; ``gid`` maps local rows back to global
  database ids.

* ``ShardedIndex`` — the stacked database placed on a 1-D ``("search",)``
  mesh: every per-record array (and the front's ``front_db``) sharded on
  its leading axis; the PQ codebook, calibration model and the front's
  ``front_rep`` pytree (IVF: the coarse centroids) replicated.

* ``ShardedExecutor`` — runs front → refine → rerank per shard under
  ``repro.compat.shard_map`` (queries replicated, database sharded).
  Equivalence with the unsharded ``SearchExecutor`` is exact, not
  approximate, because every data-dependent decision is globalized:

    - IVF front: each shard ranks the REPLICATED centroid table and
      selects the global top-``nprobe`` lists, keeping only the ones it
      owns — the union across shards is exactly the unsharded probe set;
    - graph front: the beam state (global ids, distances, expanded flags)
      is REPLICATED across shards and advances in lockstep; each hop, the
      owner of every picked node contributes its adjacency and the
      locally-computed neighbor distances (from its halo copy of the PQ
      reconstructions) to a ``psum`` frontier exchange — zeros elsewhere,
      so the summed lists are bit-exact — and the shared
      ``graph.beam_merge`` applies the exact dedup/tie-breaking the
      single-device search uses;
    - refine: pruning thresholds pool each shard's k smallest upper bounds
      with an all-gather, so the global kth smallest (and hence every
      survivor mask) matches the unsharded run bit-for-bit;
    - rerank: the SSD budget is enforced globally the same way (budget-th
      smallest estimate across shards), each shard fetches only its own
      survivors, and a final ``lax.top_k`` over all-gathered
      (distance, global id) pairs merges shard-local top-k (exact up to
      exact-f32-estimate ties at the budget boundary — see
      ``_rerank_survivors_sharded``).

  Stage counters stay device-side per shard; one host transfer at the end
  builds one ``QueryCost`` ledger PER SHARD, folded with
  ``QueryCost.merge_parallel`` (shards run concurrently: per-tier time is
  the max across shard ledgers, bytes/accesses sum).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.anns import registry
from repro.anns.executor import (_accumulate, _attach_ledger, _cat,
                                 bucket_for, fold_counts, iter_chunks,
                                 pad_chunk, search_budget)
from repro.anns.stages import (Candidates, Counters, adc_score,
                               fold_graph_front_cost, fold_ivf_front_cost,
                               graph_for, rank_centroid_lists)
from repro.compat import shard_map
from repro.core.decomposition import RecordScalars
from repro.core.estimator import pooled_k_smallest
from repro.core.trq import TRQCodes, TRQLevel
from repro.index import graph as graph_mod
from repro.memory import QueryCost, RecordLayout, Tier
from repro.obs import trace
from repro.quant import pq as pq_mod

AXIS = "search"


# ------------------------------------------------------------- partitioner


def _stack_rows(arr, rows_per_shard: list[np.ndarray], n_max: int):
    """Gather per-shard row subsets of a global (N, ...) array and stack
    them on a leading shard axis, zero-padding ragged shards to n_max."""
    a = np.asarray(arr)
    out = np.zeros((len(rows_per_shard), n_max) + a.shape[1:], a.dtype)
    for s, rows in enumerate(rows_per_shard):
        out[s, :rows.size] = a[rows]
    return jnp.asarray(out)


@dataclass(eq=False)
class ShardedIndex:
    """A FaTRQIndex partitioned into S shards, stacked on a leading axis.

    Replicated: ``codebook`` (PQ), the calibration model inside ``trq``,
    and the front's ``front_rep`` pytree (IVF: the coarse centroid table;
    graph: empty — its traversal state is the replicated beam itself).
    Sharded (leading axis S): the front's ``front_db`` pytree (IVF:
    inverted lists with LOCAL row ids; graph: subgraph adjacency + halo
    vectors + the global→local owner map), per-record
    ``pq_codes``/``trq``/``x``, and ``gid`` (local row → global id).
    ``front_args`` is the hashable tuple of static traversal parameters
    captured at partition time.
    """

    config: "PipelineConfig"         # noqa: F821 - import cycle via pipeline
    layout: RecordLayout
    n_shards: int
    front: str                       # which front this partition serves
    codebook: pq_mod.PQCodebook      # replicated
    front_rep: tuple                 # replicated front pytree
    front_db: tuple                  # sharded front pytree (leading S axis)
    front_args: tuple                # static (name, value) traversal args
    pq_codes: jax.Array              # (S, n_max, M) uint8
    trq: TRQCodes                    # every per-record leaf (S, n_max, ...)
    x: jax.Array                     # (S, n_max, D) full precision ("SSD")
    gid: jax.Array                   # (S, n_max) global row id, -1 pad
    shard_rows: np.ndarray           # (S,) host-side real row counts
    mesh: jax.sharding.Mesh | None = None

    # back-compat views of the IVF front's pytrees (pre-refactor fields)
    @property
    def centroids(self) -> jax.Array:
        return self.front_rep[0]

    @property
    def list_gid(self) -> jax.Array:
        return self.front_db[0]

    @property
    def lists(self) -> jax.Array:
        return self.front_db[1]

    def place(self, mesh) -> "ShardedIndex":
        """Place the index on a 1-D ``("search",)`` mesh: per-record arrays
        and the front_db sharded on the leading shard axis, globals
        replicated."""
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if sizes.get(AXIS) != self.n_shards:
            raise ValueError(f"mesh axis {AXIS!r} has size {sizes.get(AXIS)} "
                             f"but the index has {self.n_shards} shards")
        shard = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        put_s = lambda a: jax.device_put(a, shard)            # noqa: E731
        put_r = lambda a: jax.device_put(a, rep)              # noqa: E731
        trq = TRQCodes(
            dim=self.trq.dim,
            levels=jax.tree.map(put_s, self.trq.levels),
            scalars=jax.tree.map(put_s, self.trq.scalars),
            model=jax.tree.map(put_r, self.trq.model))
        return dataclasses.replace(
            self, mesh=mesh,
            codebook=jax.tree.map(put_r, self.codebook),
            front_rep=jax.tree.map(put_r, self.front_rep),
            front_db=jax.tree.map(put_s, self.front_db),
            pq_codes=put_s(self.pq_codes), trq=trq,
            x=put_s(self.x), gid=put_s(self.gid))


def lpt_assign(lens: np.ndarray, n_shards: int
               ) -> tuple[list[list[int]], np.ndarray]:
    """LPT greedy list→shard assignment: sort lists by member count
    descending, place each on the currently lightest shard.  Bounds the
    heaviest shard at (4/3 − 1/3S)× the optimum.  Returns (per-shard list
    ids, per-shard loads).  Shared by ``partition_database`` and the
    streaming subsystem's drift metric / ``rebalance()``
    (anns/streaming.py), so the rebalance trigger tests the exact bound
    the partitioner guarantees.
    """
    order = np.argsort(-lens, kind="stable")
    loads = np.zeros(n_shards, np.int64)
    members: list[list[int]] = [[] for _ in range(n_shards)]
    for li in order:
        s = int(np.argmin(loads))
        members[s].append(int(li))
        loads[s] += int(lens[li])
    return members, loads


def _partition_ivf_front(index, n_shards: int):
    """IVF partitioner: whole inverted lists → shards via ``lpt_assign``.
    Returns (per-shard global rows, replicated pytree, shard-stacked front
    pytree, static front args)."""
    ivf = index.ivf
    lens = np.asarray(ivf.list_len)
    lists_np = np.asarray(ivf.lists)
    nlist, cap = lists_np.shape
    if not 1 <= n_shards <= nlist:
        raise ValueError(f"n_shards={n_shards} must be in [1, nlist={nlist}]"
                         f" — whole lists are the partitioning unit")

    members, _ = lpt_assign(lens, n_shards)

    lmax = max(len(m) for m in members)
    rows_per: list[np.ndarray] = []
    list_gid = np.full((n_shards, lmax), -1, np.int32)
    local_lists = np.full((n_shards, lmax, cap), -1, np.int32)
    for s, m in enumerate(members):
        off = 0
        rows: list[np.ndarray] = []
        for j, li in enumerate(m):
            n_li = int(lens[li])
            list_gid[s, j] = li
            local_lists[s, j, :n_li] = np.arange(off, off + n_li)
            rows.append(lists_np[li, :n_li])
            off += n_li
        rows_per.append(np.concatenate(rows) if rows
                        else np.zeros((0,), np.int32))
    rep = (ivf.centroids,)
    fdb = (jnp.asarray(list_gid), jnp.asarray(local_lists))
    return rows_per, rep, fdb, (("nprobe", index.config.nprobe),)


def _partition_graph_front(index, n_shards: int):
    """Graph partitioner: contiguous vector ranges → shards, each with its
    subgraph + halo.

    Per shard: the adjacency of its owned rows both as GLOBAL ids (what the
    frontier exchange publishes) and as LOCAL slots into ``xs_loc`` — the
    shard's copy of the PQ reconstructions for its owned rows FOLLOWED BY
    every off-shard boundary neighbor (the halo).  ``loc_of`` maps global
    row → owned local row (-1 off-shard): it decides frontier-exchange
    ownership and maps the final beam onto the shard's record store.
    ``xs_loc`` is gathered from one globally-decoded array so halo copies
    are bit-identical to the owner's values.
    """
    n = int(index.x.shape[0])
    if not 1 <= n_shards <= n:
        raise ValueError(f"n_shards={n_shards} must be in [1, n={n}] — "
                         f"vectors are the partitioning unit")
    g = np.asarray(graph_for(index).neighbors)
    degree = g.shape[1]
    x_score = np.asarray(pq_mod.decode(index.codebook, index.pq_codes))
    rows_per = [r.astype(np.int32)
                for r in np.array_split(np.arange(n), n_shards)]
    ns_max = max(r.size for r in rows_per)

    loc_of = np.full((n_shards, n), -1, np.int32)
    halos: list[np.ndarray] = []
    for s, rows in enumerate(rows_per):
        loc_of[s, rows] = np.arange(rows.size, dtype=np.int32)
        nbr = g[rows]
        halos.append(np.unique(nbr[loc_of[s, nbr] < 0]))
    nloc_max = max(1, max(r.size + h.size for r, h in zip(rows_per, halos)))

    xs_loc = np.zeros((n_shards, nloc_max, x_score.shape[1]), np.float32)
    adj_gid = np.zeros((n_shards, ns_max, degree), np.int32)
    adj_loc = np.zeros((n_shards, ns_max, degree), np.int32)
    for s, (rows, halo) in enumerate(zip(rows_per, halos)):
        local = np.concatenate([rows, halo])
        xs_loc[s, :local.size] = x_score[local]
        full_loc = loc_of[s].copy()
        full_loc[halo] = rows.size + np.arange(halo.size, dtype=np.int32)
        adj_gid[s, :rows.size] = g[rows]
        adj_loc[s, :rows.size] = full_loc[g[rows]]

    fdb = (jnp.asarray(xs_loc), jnp.asarray(adj_gid),
           jnp.asarray(adj_loc), jnp.asarray(loc_of))
    # static traversal args — MUST match GraphFrontStage's defaults, the
    # single-shard baseline the equivalence tests pin against
    args = (("beam", 64), ("iters", 32), ("expand", 4), ("n", n),
            ("degree", degree))
    return rows_per, (), fdb, args


def partition_database(index, n_shards: int,
                       front: str = "ivf") -> ShardedIndex:
    """Partition ``index`` for ``front``'s sharded datapath.

    The front's registered hooks choose the scheme (whole IVF lists vs
    vector ranges + halo); the per-record arrays (PQ codes, TRQ levels +
    scalars, full vectors) are then gathered into shard-local row order the
    same way for every front, so the refine/rerank datapath indexes them
    densely regardless of how candidates were generated.
    """
    hooks = registry.sharded_front(front)
    rows_per, front_rep, front_db, front_args = hooks.partition(
        index, n_shards)
    shard_rows = np.array([r.size for r in rows_per])
    n_max = max(int(shard_rows.max()), 1)

    gid = np.full((n_shards, n_max), -1, np.int32)
    for s, rows in enumerate(rows_per):
        gid[s, :rows.size] = rows

    trq = index.trq
    levels = tuple(
        TRQLevel(packed=_stack_rows(lv.packed, rows_per, n_max),
                 proj=_stack_rows(lv.proj, rows_per, n_max),
                 norm=_stack_rows(lv.norm, rows_per, n_max),
                 rho=_stack_rows(lv.rho, rows_per, n_max))
        for lv in trq.levels)
    scalars = RecordScalars(
        delta_sq=_stack_rows(trq.scalars.delta_sq, rows_per, n_max),
        cross=_stack_rows(trq.scalars.cross, rows_per, n_max),
        rho=_stack_rows(trq.scalars.rho, rows_per, n_max),
        norm=_stack_rows(trq.scalars.norm, rows_per, n_max))

    return ShardedIndex(
        config=index.config, layout=index.layout, n_shards=n_shards,
        front=front, codebook=index.codebook,
        front_rep=front_rep, front_db=front_db, front_args=front_args,
        pq_codes=_stack_rows(index.pq_codes, rows_per, n_max),
        trq=TRQCodes(dim=trq.dim, levels=levels, scalars=scalars,
                     model=trq.model),
        x=_stack_rows(index.x, rows_per, n_max),
        gid=jnp.asarray(gid), shard_rows=shard_rows)


# ------------------------------------------------------ per-shard fronts


def _ivf_shard_front(queries, rep, fdb, codebook, pq_codes, *,
                     qvalid=None, nprobe: int) -> Candidates:
    """IVF front inside the shard_map body: rank the replicated centroid
    table globally, gather only the chosen lists this shard owns.
    ``qvalid`` (replicated (Q,) mask) zeroes padded query rows out of the
    candidate set and the counters — see ``stages.FrontStage``."""
    (centroids,) = rep
    list_gid, lists = fdb
    nq = queries.shape[0]
    lmax, cap = lists.shape

    d_cent, top_lists = rank_centroid_lists(centroids, queries,
                                            nprobe=nprobe)
    chosen = jnp.any(list_gid[None, :, None] == top_lists[:, None, :],
                     axis=-1)                                 # (Q, Lmax)
    # Gather only the chosen owned lists — the global top-nprobe set has
    # nprobe lists TOTAL across shards, so ≤ nprobe local slots always
    # suffice; scoring the whole shard would cost Lmax/nprobe× more.
    pl = min(nprobe, lmax)
    d_own = jnp.where(chosen & (list_gid >= 0)[None, :],
                      d_cent[:, jnp.maximum(list_gid, 0)], jnp.inf)
    _, slot = jax.lax.top_k(-d_own, pl)                       # (Q, pl)
    sel = jnp.take_along_axis(chosen, slot, axis=1)           # (Q, pl)
    ids_l = lists[slot]                                       # (Q, pl, cap)
    valid = ((ids_l >= 0) & sel[:, :, None]).reshape(nq, pl * cap)
    if qvalid is not None:
        valid = valid & qvalid[:, None]
    ids = jnp.maximum(ids_l.reshape(nq, pl * cap), 0)
    d0 = adc_score(codebook, pq_codes[ids], queries, valid)
    return Candidates(ids=ids, valid=valid, d0=d0,
                      counters={"front_cand": jnp.sum(valid)})


def _graph_shard_front(queries, rep, fdb, codebook, pq_codes, *,
                       qvalid=None, beam: int, iters: int, expand: int,
                       n: int, degree: int) -> Candidates:
    """Graph front inside the shard_map body: replicated beam, per-hop
    frontier exchange over the halo-partitioned subgraphs.

    The beam state (global ids, distances, expanded flags) is identical on
    every shard and advances in lockstep.  Each hop, the shared
    ``graph.pick_frontier`` selects the same picks everywhere; the OWNER of
    each picked node contributes its adjacency row (global ids) and the
    neighbor distances computed from its local ``xs_loc`` copy, everyone
    else contributes zeros, and one ``psum`` per tensor reassembles the
    exact flattened neighbor list the single-device search builds (x + 0
    is exact for finite f32, and each node has exactly one owner).  The
    shared ``graph.beam_merge`` then applies the identical dedup /
    tie-breaking, so the final beam is bit-identical to the unsharded
    ``GraphFrontStage`` — each shard claims the slots it owns and
    ADC-scores only those against its local record store.
    """
    xs_loc, adj_gid, adj_loc, loc_of = fdb
    nq = queries.shape[0]
    start = jax.random.randint(jax.random.PRNGKey(0), (beam,), 0, n)

    def owner_dist(gids):
        """(Q, ...) global ids → (owned?, psum'd exact distances)."""
        lrow = loc_of[gids]
        own = lrow >= 0
        dloc = jnp.sum(
            (xs_loc[jnp.maximum(lrow, 0)] - queries.reshape(
                (nq,) + (1,) * (gids.ndim - 1) + (-1,))) ** 2, axis=-1)
        return own, jax.lax.psum(jnp.where(own, dloc, 0.0), AXIS)

    ids0 = jnp.broadcast_to(start[None], (nq, beam))
    _, ds0 = owner_dist(ids0)
    exp0 = jnp.zeros((nq, beam), bool)

    def body(carry, _):
        ids, ds, expanded, hops = carry
        picks, expanded = jax.vmap(
            partial(graph_mod.pick_frontier, expand=expand))(ds, expanded)
        pg = jnp.take_along_axis(ids, picks, axis=1)          # (Q, E)
        pl = loc_of[pg]
        own = pl >= 0
        pls = jnp.maximum(pl, 0)
        neigh = jax.lax.psum(
            jnp.where(own[..., None], adj_gid[pls], 0), AXIS)
        # neighbor distances come from the owner's adjacency-LOCAL slots
        # (its xs_loc covers owned rows + halo, so every edge resolves)
        nd = jnp.sum((xs_loc[adj_loc[pls]]
                      - queries[:, None, None, :]) ** 2, axis=-1)
        nd = jax.lax.psum(jnp.where(own[..., None], nd, 0.0), AXIS)
        hop_own = own if qvalid is None else own & qvalid[:, None]
        hops = hops + jnp.sum(hop_own.astype(jnp.int32))
        ids, ds, expanded = jax.vmap(
            partial(graph_mod.beam_merge, beam=beam))(
            ids, ds, expanded, neigh.reshape(nq, -1), nd.reshape(nq, -1))
        return (ids, ds, expanded, hops), None

    (ids, ds, _, hops), _ = jax.lax.scan(
        body, (ids0, ds0, exp0, jnp.asarray(0, jnp.int32)), None,
        length=iters)
    order = jnp.argsort(ds, axis=1)
    beam_ids = jnp.take_along_axis(ids, order, axis=1)        # (Q, beam)

    lfin = loc_of[beam_ids]
    valid = lfin >= 0                                         # owned slots
    if qvalid is not None:
        valid = valid & qvalid[:, None]
    ids_local = jnp.maximum(lfin, 0)
    d0 = adc_score(codebook, pq_codes[ids_local], queries, valid)
    return Candidates(ids=ids_local, valid=valid, d0=d0,
                      counters={"front_cand": jnp.sum(valid),
                                "front_hops": hops * degree})


registry.register_sharded_front("ivf", registry.ShardedFrontHooks(
    partition=_partition_ivf_front, body=_ivf_shard_front,
    fold=fold_ivf_front_cost))
registry.register_sharded_front("graph", registry.ShardedFrontHooks(
    partition=_partition_graph_front, body=_graph_shard_front,
    fold=fold_graph_front_cost))


# ------------------------------------------------------ per-shard datapath


def _rerank_survivors_sharded(x, gid, queries, ids, est, alive, *, k: int,
                              budget: int, axis_name: str):
    """Shard-local exact rerank under a GLOBAL SSD budget.

    The fetch set must match the unsharded executor's exactly: take each
    shard's ``min(budget, C_s)`` best estimates, pool them with an
    all-gather to find the global budget-th smallest estimate among alive
    candidates, and fetch only local survivors at or below it.  Returns
    (exact distances, global ids, local fetch count) — distances are +inf
    outside the fetch set so the cross-shard top-k merge ignores them.

    Tie caveat: the unsharded path cuts EXACTLY ``budget`` slots with
    ``top_k`` (index-order tie-break), while this threshold cut keeps every
    candidate at ``tau_b``; records with exactly equal f32 estimates
    straddling the budget boundary (e.g. duplicate database rows) can
    therefore fetch one extra candidate per tie.  Real-valued data makes
    such exact ties measure-zero, and the two paths' candidate orderings
    differ anyway, so index-order tie-breaking is not reproducible across
    them in either direction.
    """
    bl = min(budget, est.shape[1])
    est_m = jnp.where(alive, est, jnp.inf)
    neg_local, order = jax.lax.top_k(-est_m, bl)              # (Q, bl)
    tau_b = pooled_k_smallest(est_m, budget, axis_name)       # (Q,)

    fetch_ids = jnp.take_along_axis(ids, order, axis=1)
    fetch_alive = jnp.take_along_axis(alive, order, axis=1) & \
        (-neg_local <= tau_b[:, None])
    d = jnp.sum((x[fetch_ids] - queries[:, None, :]) ** 2, axis=-1)
    d = jnp.where(fetch_alive, d, jnp.inf)
    fetch_gid = gid[fetch_ids]                                # (Q, bl)
    return d, fetch_gid, jnp.sum(fetch_alive)


def _shard_body(queries, qvalid, front_rep, codebook, model, front_db,
                rec_db, *, dim: int, k: int, budget: int, bound: str,
                z: float, backend: str, front: str, front_args: tuple):
    """One shard's front → refine → rerank, with globalized decisions.

    Runs under shard_map: ``queries``/``qvalid``/``front_rep``/
    ``codebook``/``model`` are replicated; ``front_db``/``rec_db`` leaves
    carry a leading length-1 shard-block dim.  The front's candidate
    generation comes from its registered ``ShardedFrontHooks.body``
    (``qvalid`` masks padded query rows out of candidates and counters on
    every shard identically); refine, rerank and the cross-shard merge
    are front-agnostic.
    """
    front_local = jax.tree.map(lambda a: a[0], front_db)
    pq_codes, levels, scalars, x, gid = jax.tree.map(
        lambda a: a[0], rec_db)
    trq = TRQCodes(dim=dim, levels=levels, scalars=scalars, model=model)

    # -- front: the registered per-shard body (may use mesh collectives) --
    cand = registry.sharded_front(front).body(
        queries, front_rep, front_local, codebook, pq_codes, qvalid=qvalid,
        **dict(front_args))

    # -- refine: registered backends, thresholds pooled across the axis ---
    be = registry.make_backend(backend)
    refined = be.refine(queries, cand, trq, k=k, bound=bound, z=z,
                        axis_name=AXIS)

    # -- rerank + cross-shard top-k merge ---------------------------------
    d, fetch_gid, n_ssd = _rerank_survivors_sharded(
        x, gid, queries, cand.ids, refined.est, refined.alive,
        k=k, budget=budget, axis_name=AXIS)
    d_all = jax.lax.all_gather(d, AXIS, axis=1, tiled=True)
    g_all = jax.lax.all_gather(fetch_gid, AXIS, axis=1, tiled=True)
    neg_d, best = jax.lax.top_k(-d_all, k)
    topk = jnp.take_along_axis(g_all, best, axis=1)           # replicated
    topk_d = -neg_d                                           # replicated

    counters = dict(cand.counters)
    counters.update(refined.counters)
    counters["ssd_fetch"] = n_ssd
    counters = {n: v.reshape(1).astype(jnp.int32)
                for n, v in counters.items()}                 # (1,) → (S,)
    return topk, topk_d, counters


@partial(jax.jit, static_argnames=("mesh", "dim", "k", "budget", "bound",
                                   "z", "backend", "front", "front_args"))
def _sharded_search(mesh, queries, qvalid, front_rep, codebook, trq_model,
                    front_db, rec_db, *, dim: int, k: int, budget: int,
                    bound: str, z: float, backend: str, front: str,
                    front_args: tuple):
    body = partial(_shard_body, dim=dim, k=k, budget=budget, bound=bound,
                   z=z, backend=backend, front=front, front_args=front_args)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), P(), P(), P(), P(), P(AXIS), P(AXIS)),
                   out_specs=(P(), P(), P(AXIS)),
                   check_rep=False)
    return fn(queries, qvalid, front_rep, codebook, trq_model, front_db,
              rec_db)


# ---------------------------------------------------------------- executor


@dataclass
class ShardedExecutor:
    """Mesh-parallel staged search over a ShardedIndex.

    Bit-identical top-k to the unsharded ``SearchExecutor`` on the same
    database for BOTH fronts (see module docstring for why), with
    per-shard QueryCost ledgers folded under the parallel-shard overlap
    model.
    """

    sharded: ShardedIndex
    backend: str = "reference"
    micro_batch: int | None = None
    refine_budget: int | None = None  # plan-level SSD budget override

    def __post_init__(self):
        registry.backend_spec(self.backend)   # PlanError on unknown names

    # -- construction -----------------------------------------------------

    @classmethod
    def from_index(cls, index, *, shards: int, front: str = "ivf",
                   backend: str = "reference", mesh=None,
                   micro_batch: int | None = None,
                   refine_budget: int | None = None) -> "ShardedExecutor":
        """Partition ``index`` into ``shards`` for ``front`` and place it
        on ``mesh`` (default: a fresh ``("search",)`` mesh over the first
        S devices)."""
        if mesh is None:
            from repro.launch.mesh import make_search_mesh
            mesh = make_search_mesh(shards)
        si = partition_database(index, shards, front=front).place(mesh)
        return cls(sharded=si, backend=backend, micro_batch=micro_batch,
                   refine_budget=refine_budget)

    # -- search -----------------------------------------------------------

    def execute(self, queries: jax.Array, *, k: int | None = None,
                cost: QueryCost | None = None, pad: bool = False
                ) -> tuple[jax.Array, jax.Array, QueryCost]:
        """Sharded FaTRQ search: (Q, k) GLOBAL ids, (Q, k) exact squared-L2
        distances, and the merged per-shard ledger.  ``pad=True`` pads
        ragged chunks to their power-of-two bucket (replicated validity
        mask), exactly like ``SearchExecutor.execute``."""
        si = self.sharded
        cfg = si.config
        k = k or cfg.final_k
        budget = search_budget(cfg, k, self.refine_budget)
        rec_db = (si.pq_codes, si.trq.levels, si.trq.scalars, si.x, si.gid)
        tr = trace.active()

        with trace.span("execute", track="query", front=si.front,
                        backend=self.backend, k=k, budget=budget,
                        shards=si.n_shards, fused=True,
                        n_queries=int(queries.shape[0])) as sp_ex:
            topk_parts: list[jax.Array] = []
            dist_parts: list[jax.Array] = []
            counters: Counters = {}
            for chunk in iter_chunks(queries, self.micro_batch):
                n = chunk.shape[0]
                if pad:
                    chunk, qvalid = pad_chunk(
                        chunk, bucket_for(n, self.micro_batch))
                else:
                    qvalid = jnp.ones((n,), bool)
                topk, topk_d, cnt = _sharded_search(
                    si.mesh, chunk, qvalid, si.front_rep, si.codebook,
                    si.trq.model, si.front_db, rec_db, dim=si.trq.dim, k=k,
                    budget=budget, bound=cfg.bound, z=cfg.z,
                    backend=self.backend, front=si.front,
                    front_args=si.front_args)
                if topk.shape[0] != n:             # drop padded rows
                    topk, topk_d = topk[:n], topk_d[:n]
                topk_parts.append(topk)
                dist_parts.append(topk_d)
                _accumulate(counters, cnt)
            if tr is not None:
                jax.block_until_ready(topk_parts[-1])

            merged = self._fold(counters)
            if tr is not None:
                # the shard_map body fuses front/refine/rerank into one
                # compiled region — no host-side stage boundaries exist to
                # time, so emit model-attributed stage events instead
                # (fused=True) to keep the span↔ledger coverage invariant
                # on the sharded layout.
                sid = sp_ex.span.sid
                for stage, tier in (("front", Tier.HBM),
                                    ("refine", Tier.CXL),
                                    ("rerank", Tier.SSD)):
                    tr.event(stage, track="query", parent=sid, fused=True,
                             model_s=merged.tier_seconds(tier))
                _attach_ledger(sp_ex, merged)
            if cost is not None:
                merged = cost.merge(merged)
        return _cat(topk_parts), _cat(dist_parts), merged

    def search(self, queries: jax.Array, *, k: int | None = None,
               cost: QueryCost | None = None) -> tuple[jax.Array, QueryCost]:
        """Legacy tuple surface: (Q, k) GLOBAL ids + the merged ledger."""
        ids, _, merged = self.execute(queries, k=k, cost=cost)
        return ids, merged

    # -- cost folding -----------------------------------------------------

    def _fold(self, counters: Counters) -> QueryCost:
        """One host transfer: (S,)-stacked shard counters → S Table-I
        ledgers → one parallel-folded QueryCost (max time, summed bytes).
        The front's registered fold keeps per-front traffic models (IVF
        coarse probe vs graph hop stream) consistent with the unsharded
        stages."""
        si = self.sharded
        front_fold = registry.sharded_front(si.front).fold
        names = list(counters)
        vals = jax.device_get([counters[n] for n in names])

        shard_costs = []
        for s in range(si.n_shards):
            counts = {n: int(v[s]) for n, v in zip(names, vals)}
            shard_costs.append(fold_counts(
                counts, cost=None, config=si.config, layout=si.layout,
                front_fold=front_fold))
        merged = shard_costs[0]
        for c in shard_costs[1:]:
            merged.merge_parallel(c)
        return merged


def make_sharded_executor(index, *, shards: int, front: str = "ivf",
                          backend: str = "reference",
                          micro_batch: int | None = None,
                          refine_budget: int | None = None, mesh=None
                          ) -> ShardedExecutor:
    """Memoized sharded-executor factory (facade entry point).

    Partitioning + placement run once per (index, shards, front);
    executors are additionally cached per (backend, micro_batch,
    refine_budget) so ``anns.pipeline`` and ``serving`` can call this on
    every request.
    """
    key = (shards, front, backend, micro_batch, refine_budget, mesh)
    cache = getattr(index, "_sharded_cache", None)
    if cache is None:
        cache = {}
        index._sharded_cache = cache
    ex = cache.get(key)
    if ex is None:
        si = None
        # share the partitioned+placed index only across entries with the
        # SAME (shards, front, mesh) request — a default (mesh=None) call
        # must not silently adopt a custom-mesh placement and vice versa
        for (sh, _f, _b, _m, _rb, _mesh), other in cache.items():
            if sh == shards and _f == front and _mesh is mesh:
                si = other.sharded
                break
        if si is None:
            ex = ShardedExecutor.from_index(index, shards=shards,
                                            front=front, backend=backend,
                                            mesh=mesh,
                                            micro_batch=micro_batch,
                                            refine_budget=refine_budget)
        else:
            ex = ShardedExecutor(sharded=si, backend=backend,
                                 micro_batch=micro_batch,
                                 refine_budget=refine_budget)
        cache[key] = ex
    return ex
