"""Sharded search subsystem: mesh-partitioned database + shard_map datapath.

Scale-out of the staged executor across devices (paper Fig. 6 scales
throughput by replicating the refinement datapath across far-memory
channels; COSMOS/HAVEN reach billion-scale by partitioning the candidate
datapath).  Three pieces:

* ``partition_database`` — IVF-list-aware partitioner: WHOLE inverted
  lists are assigned to shards (a candidate's codes, scalars and full
  vector co-reside with its list), balanced by list length with an LPT
  greedy (heaviest list onto the lightest shard).  Per-shard arrays are
  stacked on a leading shard axis and row ids are re-indexed shard-locally;
  ``gid`` maps local rows back to global database ids.

* ``ShardedIndex`` — the stacked database placed on a 1-D ``("search",)``
  mesh: every per-record array sharded on its leading axis, the coarse
  centroids / PQ codebook / calibration model replicated.

* ``ShardedExecutor`` — runs the existing front → refine → rerank stages
  per shard under ``repro.compat.shard_map`` (queries replicated, database
  sharded).  Equivalence with the unsharded ``SearchExecutor`` is exact,
  not approximate, because every data-dependent decision is globalized:

    - front: each shard ranks the REPLICATED centroid table and selects
      the global top-``nprobe`` lists, keeping only the ones it owns — the
      union across shards is exactly the unsharded probe set;
    - refine: pruning thresholds pool each shard's k smallest upper bounds
      with an all-gather, so the global kth smallest (and hence every
      survivor mask) matches the unsharded run bit-for-bit;
    - rerank: the SSD budget is enforced globally the same way (budget-th
      smallest estimate across shards), each shard fetches only its own
      survivors, and a final ``lax.top_k`` over all-gathered
      (distance, global id) pairs merges shard-local top-k (exact up to
      exact-f32-estimate ties at the budget boundary — see
      ``_rerank_survivors_sharded``).

  Stage counters stay device-side per shard; one host transfer at the end
  builds one ``QueryCost`` ledger PER SHARD, folded with
  ``QueryCost.merge_parallel`` (shards run concurrently: per-tier time is
  the max across shard ledgers, bytes/accesses sum).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.anns import registry
from repro.anns.executor import (_accumulate, _cat, fold_counts,
                                 iter_chunks, search_budget)
from repro.anns.stages import (Candidates, Counters, adc_score,
                               fold_ivf_front_cost, rank_centroid_lists)
from repro.compat import shard_map
from repro.core.decomposition import RecordScalars
from repro.core.estimator import pooled_k_smallest
from repro.core.trq import TRQCodes, TRQLevel
from repro.memory import QueryCost, RecordLayout
from repro.quant import pq as pq_mod

AXIS = "search"


# ------------------------------------------------------------- partitioner


def _stack_rows(arr, rows_per_shard: list[np.ndarray], n_max: int):
    """Gather per-shard row subsets of a global (N, ...) array and stack
    them on a leading shard axis, zero-padding ragged shards to n_max."""
    a = np.asarray(arr)
    out = np.zeros((len(rows_per_shard), n_max) + a.shape[1:], a.dtype)
    for s, rows in enumerate(rows_per_shard):
        out[s, :rows.size] = a[rows]
    return jnp.asarray(out)


@dataclass(eq=False)
class ShardedIndex:
    """A FaTRQIndex partitioned into S shards, stacked on a leading axis.

    Replicated: ``centroids`` (coarse table), ``codebook`` (PQ), and the
    calibration model inside ``trq``.  Sharded (leading axis S):
    ``list_gid``/``lists`` (inverted lists with LOCAL row ids), per-record
    ``pq_codes``/``trq``/``x``, and ``gid`` (local row → global id).
    """

    config: "PipelineConfig"         # noqa: F821 - import cycle via pipeline
    layout: RecordLayout
    n_shards: int
    centroids: jax.Array             # (nlist, D) replicated
    codebook: pq_mod.PQCodebook      # replicated
    list_gid: jax.Array              # (S, Lmax) global list id, -1 pad
    lists: jax.Array                 # (S, Lmax, cap) LOCAL row ids, -1 pad
    pq_codes: jax.Array              # (S, n_max, M) uint8
    trq: TRQCodes                    # every per-record leaf (S, n_max, ...)
    x: jax.Array                     # (S, n_max, D) full precision ("SSD")
    gid: jax.Array                   # (S, n_max) global row id, -1 pad
    shard_rows: np.ndarray           # (S,) host-side real row counts
    mesh: jax.sharding.Mesh | None = None

    def place(self, mesh) -> "ShardedIndex":
        """Place the index on a 1-D ``("search",)`` mesh: per-record arrays
        sharded on the leading shard axis, globals replicated."""
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if sizes.get(AXIS) != self.n_shards:
            raise ValueError(f"mesh axis {AXIS!r} has size {sizes.get(AXIS)} "
                             f"but the index has {self.n_shards} shards")
        shard = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        put_s = lambda a: jax.device_put(a, shard)            # noqa: E731
        put_r = lambda a: jax.device_put(a, rep)              # noqa: E731
        trq = TRQCodes(
            dim=self.trq.dim,
            levels=jax.tree.map(put_s, self.trq.levels),
            scalars=jax.tree.map(put_s, self.trq.scalars),
            model=jax.tree.map(put_r, self.trq.model))
        return dataclasses.replace(
            self, mesh=mesh,
            centroids=put_r(self.centroids),
            codebook=jax.tree.map(put_r, self.codebook),
            list_gid=put_s(self.list_gid), lists=put_s(self.lists),
            pq_codes=put_s(self.pq_codes), trq=trq,
            x=put_s(self.x), gid=put_s(self.gid))


def lpt_assign(lens: np.ndarray, n_shards: int
               ) -> tuple[list[list[int]], np.ndarray]:
    """LPT greedy list→shard assignment: sort lists by member count
    descending, place each on the currently lightest shard.  Bounds the
    heaviest shard at (4/3 − 1/3S)× the optimum.  Returns (per-shard list
    ids, per-shard loads).  Shared by ``partition_database`` and the
    streaming subsystem's drift metric / ``rebalance()``
    (anns/streaming.py), so the rebalance trigger tests the exact bound
    the partitioner guarantees.
    """
    order = np.argsort(-lens, kind="stable")
    loads = np.zeros(n_shards, np.int64)
    members: list[list[int]] = [[] for _ in range(n_shards)]
    for li in order:
        s = int(np.argmin(loads))
        members[s].append(int(li))
        loads[s] += int(lens[li])
    return members, loads


def partition_database(index, n_shards: int) -> ShardedIndex:
    """IVF-list-aware partitioner: whole inverted lists → shards.

    Lists are assigned with the ``lpt_assign`` greedy.  All per-record
    arrays (PQ codes, TRQ levels + scalars, full vectors) are gathered into
    shard-local row order so the per-shard datapath indexes them densely.
    """
    ivf = index.ivf
    lens = np.asarray(ivf.list_len)
    lists_np = np.asarray(ivf.lists)
    nlist, cap = lists_np.shape
    if not 1 <= n_shards <= nlist:
        raise ValueError(f"n_shards={n_shards} must be in [1, nlist={nlist}]"
                         f" — whole lists are the partitioning unit")

    members, _ = lpt_assign(lens, n_shards)

    lmax = max(len(m) for m in members)
    rows_per: list[np.ndarray] = []
    list_gid = np.full((n_shards, lmax), -1, np.int32)
    local_lists = np.full((n_shards, lmax, cap), -1, np.int32)
    for s, m in enumerate(members):
        off = 0
        rows: list[np.ndarray] = []
        for j, li in enumerate(m):
            n_li = int(lens[li])
            list_gid[s, j] = li
            local_lists[s, j, :n_li] = np.arange(off, off + n_li)
            rows.append(lists_np[li, :n_li])
            off += n_li
        rows_per.append(np.concatenate(rows) if rows
                        else np.zeros((0,), np.int32))
    shard_rows = np.array([r.size for r in rows_per])
    n_max = max(int(shard_rows.max()), 1)

    gid = np.full((n_shards, n_max), -1, np.int32)
    for s, rows in enumerate(rows_per):
        gid[s, :rows.size] = rows

    trq = index.trq
    levels = tuple(
        TRQLevel(packed=_stack_rows(lv.packed, rows_per, n_max),
                 proj=_stack_rows(lv.proj, rows_per, n_max),
                 norm=_stack_rows(lv.norm, rows_per, n_max),
                 rho=_stack_rows(lv.rho, rows_per, n_max))
        for lv in trq.levels)
    scalars = RecordScalars(
        delta_sq=_stack_rows(trq.scalars.delta_sq, rows_per, n_max),
        cross=_stack_rows(trq.scalars.cross, rows_per, n_max),
        rho=_stack_rows(trq.scalars.rho, rows_per, n_max),
        norm=_stack_rows(trq.scalars.norm, rows_per, n_max))

    return ShardedIndex(
        config=index.config, layout=index.layout, n_shards=n_shards,
        centroids=ivf.centroids, codebook=index.codebook,
        list_gid=jnp.asarray(list_gid), lists=jnp.asarray(local_lists),
        pq_codes=_stack_rows(index.pq_codes, rows_per, n_max),
        trq=TRQCodes(dim=trq.dim, levels=levels, scalars=scalars,
                     model=trq.model),
        x=_stack_rows(index.x, rows_per, n_max),
        gid=jnp.asarray(gid), shard_rows=shard_rows)


# ------------------------------------------------------ per-shard datapath


def _rerank_survivors_sharded(x, gid, queries, ids, est, alive, *, k: int,
                              budget: int, axis_name: str):
    """Shard-local exact rerank under a GLOBAL SSD budget.

    The fetch set must match the unsharded executor's exactly: take each
    shard's ``min(budget, C_s)`` best estimates, pool them with an
    all-gather to find the global budget-th smallest estimate among alive
    candidates, and fetch only local survivors at or below it.  Returns
    (exact distances, global ids, local fetch count) — distances are +inf
    outside the fetch set so the cross-shard top-k merge ignores them.

    Tie caveat: the unsharded path cuts EXACTLY ``budget`` slots with
    ``top_k`` (index-order tie-break), while this threshold cut keeps every
    candidate at ``tau_b``; records with exactly equal f32 estimates
    straddling the budget boundary (e.g. duplicate database rows) can
    therefore fetch one extra candidate per tie.  Real-valued data makes
    such exact ties measure-zero, and the two paths' candidate orderings
    differ anyway, so index-order tie-breaking is not reproducible across
    them in either direction.
    """
    bl = min(budget, est.shape[1])
    est_m = jnp.where(alive, est, jnp.inf)
    neg_local, order = jax.lax.top_k(-est_m, bl)              # (Q, bl)
    tau_b = pooled_k_smallest(est_m, budget, axis_name)       # (Q,)

    fetch_ids = jnp.take_along_axis(ids, order, axis=1)
    fetch_alive = jnp.take_along_axis(alive, order, axis=1) & \
        (-neg_local <= tau_b[:, None])
    d = jnp.sum((x[fetch_ids] - queries[:, None, :]) ** 2, axis=-1)
    d = jnp.where(fetch_alive, d, jnp.inf)
    fetch_gid = gid[fetch_ids]                                # (Q, bl)
    return d, fetch_gid, jnp.sum(fetch_alive)


def _shard_body(queries, centroids, codebook, model, db, *, dim: int,
                nprobe: int, k: int, budget: int, bound: str, z: float,
                backend: str):
    """One shard's front → refine → rerank, with globalized decisions.

    Runs under shard_map: ``queries``/``centroids``/``codebook``/``model``
    are replicated, ``db`` leaves carry a leading length-1 shard-block dim.
    """
    list_gid, lists, pq_codes, levels, scalars, x, gid = jax.tree.map(
        lambda a: a[0], db)
    trq = TRQCodes(dim=dim, levels=levels, scalars=scalars, model=model)
    nq = queries.shape[0]
    lmax, cap = lists.shape

    # -- front: rank the replicated centroid table, keep owned lists ------
    d_cent, top_lists = rank_centroid_lists(centroids, queries,
                                            nprobe=nprobe)
    chosen = jnp.any(list_gid[None, :, None] == top_lists[:, None, :],
                     axis=-1)                                 # (Q, Lmax)
    # Gather only the chosen owned lists — the global top-nprobe set has
    # nprobe lists TOTAL across shards, so ≤ nprobe local slots always
    # suffice; scoring the whole shard would cost Lmax/nprobe× more.
    pl = min(nprobe, lmax)
    d_own = jnp.where(chosen & (list_gid >= 0)[None, :],
                      d_cent[:, jnp.maximum(list_gid, 0)], jnp.inf)
    _, slot = jax.lax.top_k(-d_own, pl)                       # (Q, pl)
    sel = jnp.take_along_axis(chosen, slot, axis=1)           # (Q, pl)
    ids_l = lists[slot]                                       # (Q, pl, cap)
    valid = ((ids_l >= 0) & sel[:, :, None]).reshape(nq, pl * cap)
    ids = jnp.maximum(ids_l.reshape(nq, pl * cap), 0)
    d0 = adc_score(codebook, pq_codes[ids], queries, valid)
    cand = Candidates(ids=ids, valid=valid, d0=d0,
                      counters={"front_cand": jnp.sum(valid)})

    # -- refine: registered backends, thresholds pooled across the axis ---
    be = registry.make_backend(backend)
    refined = be.refine(queries, cand, trq, k=k, bound=bound, z=z,
                        axis_name=AXIS)

    # -- rerank + cross-shard top-k merge ---------------------------------
    d, fetch_gid, n_ssd = _rerank_survivors_sharded(
        x, gid, queries, cand.ids, refined.est, refined.alive,
        k=k, budget=budget, axis_name=AXIS)
    d_all = jax.lax.all_gather(d, AXIS, axis=1, tiled=True)
    g_all = jax.lax.all_gather(fetch_gid, AXIS, axis=1, tiled=True)
    neg_d, best = jax.lax.top_k(-d_all, k)
    topk = jnp.take_along_axis(g_all, best, axis=1)           # replicated
    topk_d = -neg_d                                           # replicated

    counters = dict(cand.counters)
    counters.update(refined.counters)
    counters["ssd_fetch"] = n_ssd
    counters = {n: v.reshape(1).astype(jnp.int32)
                for n, v in counters.items()}                 # (1,) → (S,)
    return topk, topk_d, counters


@partial(jax.jit, static_argnames=("mesh", "dim", "nprobe", "k", "budget",
                                   "bound", "z", "backend"))
def _sharded_search(mesh, queries, centroids, codebook, trq_model, db, *,
                    dim: int, nprobe: int, k: int, budget: int, bound: str,
                    z: float, backend: str):
    body = partial(_shard_body, dim=dim, nprobe=nprobe, k=k, budget=budget,
                   bound=bound, z=z, backend=backend)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(), P(), P(), P(), P(AXIS)),
                   out_specs=(P(), P(), P(AXIS)),
                   check_rep=False)
    return fn(queries, centroids, codebook, trq_model, db)


# ---------------------------------------------------------------- executor


@dataclass
class ShardedExecutor:
    """Mesh-parallel staged search over a ShardedIndex.

    Bit-identical top-k to the unsharded ``SearchExecutor`` on the same
    database (see module docstring for why), with per-shard QueryCost
    ledgers folded under the parallel-shard overlap model.
    """

    sharded: ShardedIndex
    backend: str = "reference"
    micro_batch: int | None = None
    refine_budget: int | None = None  # plan-level SSD budget override

    def __post_init__(self):
        registry.backend_spec(self.backend)   # PlanError on unknown names

    # -- construction -----------------------------------------------------

    @classmethod
    def from_index(cls, index, *, shards: int, backend: str = "reference",
                   mesh=None, micro_batch: int | None = None,
                   refine_budget: int | None = None) -> "ShardedExecutor":
        """Partition ``index`` into ``shards`` and place it on ``mesh``
        (default: a fresh ``("search",)`` mesh over the first S devices)."""
        if mesh is None:
            from repro.launch.mesh import make_search_mesh
            mesh = make_search_mesh(shards)
        si = partition_database(index, shards).place(mesh)
        return cls(sharded=si, backend=backend, micro_batch=micro_batch,
                   refine_budget=refine_budget)

    # -- search -----------------------------------------------------------

    def execute(self, queries: jax.Array, *, k: int | None = None,
                cost: QueryCost | None = None
                ) -> tuple[jax.Array, jax.Array, QueryCost]:
        """Sharded FaTRQ search: (Q, k) GLOBAL ids, (Q, k) exact squared-L2
        distances, and the merged per-shard ledger."""
        si = self.sharded
        cfg = si.config
        k = k or cfg.final_k
        budget = search_budget(cfg, k, self.refine_budget)
        db = (si.list_gid, si.lists, si.pq_codes, si.trq.levels,
              si.trq.scalars, si.x, si.gid)

        topk_parts: list[jax.Array] = []
        dist_parts: list[jax.Array] = []
        counters: Counters = {}
        for chunk in iter_chunks(queries, self.micro_batch):
            topk, topk_d, cnt = _sharded_search(
                si.mesh, chunk, si.centroids, si.codebook, si.trq.model, db,
                dim=si.trq.dim, nprobe=cfg.nprobe, k=k, budget=budget,
                bound=cfg.bound, z=cfg.z, backend=self.backend)
            topk_parts.append(topk)
            dist_parts.append(topk_d)
            _accumulate(counters, cnt)

        merged = self._fold(counters)
        if cost is not None:
            merged = cost.merge(merged)
        return _cat(topk_parts), _cat(dist_parts), merged

    def search(self, queries: jax.Array, *, k: int | None = None,
               cost: QueryCost | None = None) -> tuple[jax.Array, QueryCost]:
        """Legacy tuple surface: (Q, k) GLOBAL ids + the merged ledger."""
        ids, _, merged = self.execute(queries, k=k, cost=cost)
        return ids, merged

    # -- cost folding -----------------------------------------------------

    def _fold(self, counters: Counters) -> QueryCost:
        """One host transfer: (S,)-stacked shard counters → S Table-I
        ledgers → one parallel-folded QueryCost (max time, summed bytes)."""
        si = self.sharded
        names = list(counters)
        vals = jax.device_get([counters[n] for n in names])

        shard_costs = []
        for s in range(si.n_shards):
            counts = {n: int(v[s]) for n, v in zip(names, vals)}
            shard_costs.append(fold_counts(
                counts, cost=None, config=si.config, layout=si.layout,
                front_fold=fold_ivf_front_cost))
        merged = shard_costs[0]
        for c in shard_costs[1:]:
            merged.merge_parallel(c)
        return merged


def make_sharded_executor(index, *, shards: int, backend: str = "reference",
                          micro_batch: int | None = None,
                          refine_budget: int | None = None, mesh=None
                          ) -> ShardedExecutor:
    """Memoized sharded-executor factory (facade entry point).

    Partitioning + placement run once per (index, shards); executors are
    additionally cached per (backend, micro_batch, refine_budget) so
    ``anns.pipeline`` and ``serving`` can call this on every request.
    """
    key = (shards, backend, micro_batch, refine_budget, mesh)
    cache = getattr(index, "_sharded_cache", None)
    if cache is None:
        cache = {}
        index._sharded_cache = cache
    ex = cache.get(key)
    if ex is None:
        si = None
        # share the partitioned+placed index only across entries with the
        # SAME mesh request — a default (mesh=None) call must not silently
        # adopt a custom-mesh placement and vice versa
        for (sh, _b, _m, _rb, _mesh), other in cache.items():
            if sh == shards and _mesh is mesh:
                si = other.sharded
                break
        if si is None:
            ex = ShardedExecutor.from_index(index, shards=shards,
                                            backend=backend, mesh=mesh,
                                            micro_batch=micro_batch,
                                            refine_budget=refine_budget)
        else:
            ex = ShardedExecutor(sharded=si, backend=backend,
                                 micro_batch=micro_batch,
                                 refine_budget=refine_budget)
        cache[key] = ex
    return ex
