"""FaTRQ ANNS package — staged search over a tiered-memory index.

Layers, bottom-up:

* ``stages`` — pluggable front stages (IVF, graph) and refine backends
  (reference jnp, fused Pallas kernel), each emitting device-side traffic
  counters; ``axis_name`` switches the pruning thresholds to global
  (all-gathered) operation inside a ``shard_map``.
* ``executor`` — ``SearchExecutor`` runs front → refine → rerank fully
  batched over query micro-batches and folds the counters into a
  ``memory.QueryCost`` ledger with one host transfer per search.
* ``sharding`` — scale-out: ``partition_database`` splits the database
  per the front's registered partitioner (whole IVF lists for the IVF
  front; vector ranges + halo edges for the graph front),
  ``ShardedIndex`` places the stacked arrays on a 1-D ``("search",)``
  mesh, and ``ShardedExecutor`` runs the same stages per shard under
  ``shard_map`` (the graph front exchanges its beam frontier across
  shards each hop), merging per-shard top-k and folding per-shard
  ledgers with ``QueryCost.merge_parallel`` (max time, summed bytes).
  Top-k ids are bit-identical to the unsharded executor (up to exact-f32
  estimate ties at the SSD budget boundary, e.g. duplicate rows — see
  ``sharding._rerank_survivors_sharded``).
* ``streaming`` — the mutable layer: ``StreamingIndex`` wraps a built
  index with online ``insert``/``delete`` (incremental TRQ encode, per-list
  delta spill pages, tombstone bitmap, online graph edge insertion), a
  generation-aware search path — base ∪ delta IVF probe or graph beam
  traversal over the maintained adjacency — under one QueryCost ledger
  (delta traffic on a distinct ``delta:cxl`` entry), and drift-triggered
  ``compact()`` / ``rebalance()`` through the same LPT partitioner the
  sharded subsystem uses.
* ``tiered`` — adaptive placement: ``TieredIndex`` wraps a static index
  with heat-driven hot/warm/cold list placement (``memory.placement``).
  Hot lists score exactly from HBM and skip refinement (``hot:hbm``),
  warm lists run the normal TRQ path, cold lists' residual stream bills
  at SSD rates (``cold:ssd``); ``rebalance_tiers()`` migrates placement
  and bumps the generation so executor + result caches invalidate.
* ``registry`` — the capability registry: every front stage and refine
  backend declares the index layouts (static / sharded / streaming /
  tiered) it supports via ``register_front`` / ``register_backend``;
  unsupported combinations raise ``PlanError`` at plan time.
* ``api`` — the unified query surface: ``Database`` (one handle over
  ``FaTRQIndex`` / ``ShardedIndex`` / ``StreamingIndex`` /
  ``TieredIndex``), ``QueryPlan`` (frozen plan, validated once, compiled
  once into an executor cached per (index generation, plan)), and
  ``SearchResult`` (ids + exact distances + QueryCost + the resolved
  plan).
* ``pipeline`` — the stable facade: ``build`` (offline index build) and
  ``search(..., front=, backend=, shards=)`` / ``baseline_search`` /
  ``recall_at_k`` — thin shims over ``api.Database``, kept bit-identical
  to their pre-plan-layer behavior.
"""

from repro.anns.api import (CompiledPlan, Database, PlanError, QueryPlan,
                            SearchResult)
from repro.anns.executor import SearchExecutor, make_executor
from repro.anns.pipeline import (FaTRQIndex, PipelineConfig, baseline_search,
                                 build, recall_at_k, search)
from repro.anns.registry import register_backend, register_front
from repro.anns.sharding import (ShardedExecutor, ShardedIndex,
                                 make_sharded_executor, partition_database)
from repro.anns.stages import (Candidates, FrontStage, GraphFrontStage,
                               IVFFrontStage, PallasRefineBackend, Refined,
                               RefineBackend, ReferenceRefineBackend)
from repro.anns.streaming import StreamingConfig, StreamingIndex
from repro.anns.tiered import TieredFrontStage, TieredIndex
from repro.memory.placement import TieredConfig

__all__ = ["FaTRQIndex", "PipelineConfig", "baseline_search", "build",
           "recall_at_k", "search",
           "CompiledPlan", "Database", "QueryPlan", "SearchResult",
           "PlanError",
           "register_front", "register_backend",
           "SearchExecutor", "make_executor",
           "ShardedExecutor", "ShardedIndex", "make_sharded_executor",
           "partition_database",
           "StreamingConfig", "StreamingIndex",
           "TieredConfig", "TieredFrontStage", "TieredIndex",
           "Candidates", "Refined", "FrontStage", "RefineBackend",
           "IVFFrontStage", "GraphFrontStage",
           "ReferenceRefineBackend", "PallasRefineBackend"]
