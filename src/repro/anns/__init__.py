from repro.anns.executor import SearchExecutor, make_executor
from repro.anns.pipeline import (FaTRQIndex, PipelineConfig, baseline_search,
                                 build, recall_at_k, search)
from repro.anns.stages import (Candidates, FrontStage, GraphFrontStage,
                               IVFFrontStage, PallasRefineBackend, Refined,
                               RefineBackend, ReferenceRefineBackend)

__all__ = ["FaTRQIndex", "PipelineConfig", "baseline_search", "build",
           "recall_at_k", "search",
           "SearchExecutor", "make_executor",
           "Candidates", "Refined", "FrontStage", "RefineBackend",
           "IVFFrontStage", "GraphFrontStage",
           "ReferenceRefineBackend", "PallasRefineBackend"]
