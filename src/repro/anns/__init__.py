from repro.anns.pipeline import (FaTRQIndex, PipelineConfig, baseline_search,
                                 build, recall_at_k, search)

__all__ = ["FaTRQIndex", "PipelineConfig", "baseline_search", "build",
           "recall_at_k", "search"]
