"""FaTRQ ANNS package — staged search over a tiered-memory index.

Layers, bottom-up:

* ``stages`` — pluggable front stages (IVF, graph) and refine backends
  (reference jnp, fused Pallas kernel), each emitting device-side traffic
  counters; ``axis_name`` switches the pruning thresholds to global
  (all-gathered) operation inside a ``shard_map``.
* ``executor`` — ``SearchExecutor`` runs front → refine → rerank fully
  batched over query micro-batches and folds the counters into a
  ``memory.QueryCost`` ledger with one host transfer per search.
* ``sharding`` — scale-out: ``partition_database`` splits whole IVF lists
  across shards, ``ShardedIndex`` places the stacked arrays on a 1-D
  ``("search",)`` mesh, and ``ShardedExecutor`` runs the same stages per
  shard under ``shard_map``, merging per-shard top-k and folding per-shard
  ledgers with ``QueryCost.merge_parallel`` (max time, summed bytes).
  Top-k ids are bit-identical to the unsharded executor (up to exact-f32
  estimate ties at the SSD budget boundary, e.g. duplicate rows — see
  ``sharding._rerank_survivors_sharded``).
* ``streaming`` — the mutable layer: ``StreamingIndex`` wraps a built
  index with online ``insert``/``delete`` (incremental TRQ encode, per-list
  delta spill pages, tombstone bitmap), a generation-aware search path that
  probes base ∪ delta lists under one QueryCost ledger (delta traffic on a
  distinct ``delta:cxl`` entry), and drift-triggered ``compact()`` /
  ``rebalance()`` through the same LPT partitioner the sharded subsystem
  uses.
* ``pipeline`` — the stable facade: ``build`` (offline index build) and
  ``search(..., front=, backend=, shards=)`` / ``baseline_search`` /
  ``recall_at_k`` (``search`` also accepts a ``StreamingIndex``).
"""

from repro.anns.executor import SearchExecutor, make_executor
from repro.anns.pipeline import (FaTRQIndex, PipelineConfig, baseline_search,
                                 build, recall_at_k, search)
from repro.anns.sharding import (ShardedExecutor, ShardedIndex,
                                 make_sharded_executor, partition_database)
from repro.anns.stages import (Candidates, FrontStage, GraphFrontStage,
                               IVFFrontStage, PallasRefineBackend, Refined,
                               RefineBackend, ReferenceRefineBackend)
from repro.anns.streaming import StreamingConfig, StreamingIndex

__all__ = ["FaTRQIndex", "PipelineConfig", "baseline_search", "build",
           "recall_at_k", "search",
           "SearchExecutor", "make_executor",
           "ShardedExecutor", "ShardedIndex", "make_sharded_executor",
           "partition_database",
           "StreamingConfig", "StreamingIndex",
           "Candidates", "Refined", "FrontStage", "RefineBackend",
           "IVFFrontStage", "GraphFrontStage",
           "ReferenceRefineBackend", "PallasRefineBackend"]
