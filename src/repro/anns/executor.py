"""Staged search executor: front → refine → rerank over query micro-batches.

``SearchExecutor`` composes the pluggable stages defined in ``stages.py``
into the paper's pipelined datapath (Fig. 5) and owns the cost accounting:
each stage emits device-side counters (0-d int32 arrays), the executor
accumulates them across micro-batches *on device*, and a single host
transfer at the end of ``search`` folds the totals into a
``memory.QueryCost`` ledger — replacing the per-stage ``int(jnp.sum(...))``
round-trips the old monolithic pipeline did.

Construction is cheap (stages hold references to index arrays; all device
functions are module-level jits, so compilation caches globally), except
``front="graph"`` which builds the kNN graph on first use and caches it on
the index per degree (``stages.graph_for``).  ``make_executor`` memoizes
executors per index so facade callers (``anns.pipeline``, ``serving``) can
call it per search.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.anns import registry, stages as stages_mod
from repro.anns.stages import (Counters, FrontStage, RefineBackend,
                               graph_for as _graph_for)  # noqa: F401 - compat
from repro.index import graph as graph_mod
from repro.memory import QueryCost, Tier
from repro.memory.placement import TIER_COLD, TIER_HOT
from repro.obs import metrics, trace

# import-time snapshots of the capability registry, kept as module
# constants for pre-registry callers (stages.py has registered the
# built-ins by this point).  Stages registered later are visible only via
# anns.registry.front_names()/backend_names() — consult those for the
# live set.
FRONT_STAGES = registry.front_names()
REFINE_BACKENDS = registry.backend_names()

# measured scale of ADC + ternary adds per candidate (see benchmarks)
_COMPUTE_S_PER_CAND = 1e-7

# wall/modeled drift ratio buckets: <1 means the tier model over-charges,
# large values are expected on the interpreted CPU backend
_DRIFT_BUCKETS = (0.25, 0.5, 1.0, 2.0, 5.0, 10.0, 100.0, 1_000.0,
                  10_000.0, 100_000.0)


def _accumulate(total: Counters, new: Counters) -> Counters:
    for name, v in new.items():
        total[name] = total[name] + v if name in total else v
    return total


def search_budget(config, k: int, override: int | None = None) -> int:
    """SSD rerank budget for a search call: the configured budget, with a
    4k/32 default, floored at k (k results need ≥ k fetches).  Shared by
    the unsharded and sharded executors — their top-k equivalence depends
    on deriving the SAME budget.  ``override`` is a plan-level budget
    (``QueryPlan.refine_budget``) taking precedence over the config's."""
    return max(override or config.refine_budget or max(4 * k, 32), k)


def iter_chunks(queries: jax.Array, micro_batch: int | None):
    """Split a query batch into device-sized micro-batches (None = all)."""
    if micro_batch is None or micro_batch >= queries.shape[0]:
        yield queries
        return
    for i in range(0, queries.shape[0], micro_batch):
        yield queries[i:i + micro_batch]


def bucket_for(n: int, micro_batch: int | None = None) -> int:
    """Smallest compiled batch bucket covering ``n`` queries.

    Buckets are powers of two, capped at ``micro_batch`` (the full-chunk
    shape, which is always compiled anyway).  Padding ragged chunks up to
    a bucket keeps the set of traced query shapes at
    {1, 2, 4, ..., micro_batch} regardless of caller batch sizes, so a
    serving layer coalescing variable-size request batches NEVER
    recompiles the stage jits per batch."""
    b = 1
    while b < n:
        b <<= 1
    if micro_batch is not None and b > micro_batch >= n:
        b = micro_batch
    return b


def pad_chunk(chunk: jax.Array, bucket: int
              ) -> tuple[jax.Array, jax.Array]:
    """Zero-pad a (n, D) chunk to ``bucket`` rows; returns the padded
    chunk plus the (bucket,) per-query validity mask.  The mask is always
    a device ARRAY (all-True when n == bucket) so full and padded batches
    of the same bucket share one trace."""
    n = chunk.shape[0]
    qvalid = jnp.arange(bucket) < n
    if n == bucket:
        return chunk, qvalid
    pad = jnp.zeros((bucket - n,) + chunk.shape[1:], chunk.dtype)
    return jnp.concatenate([chunk, pad], axis=0), qvalid


def _collect(counters: Counters) -> dict:
    """The single device→host transfer of a search call.  Scalar counters
    come back as Python ints; vector counters (the tiered layout's
    per-list ``list_heat`` histogram) as numpy arrays."""
    out = {}
    for n, v in zip(counters, jax.device_get(list(counters.values()))):
        a = np.asarray(v)
        out[n] = int(a) if a.ndim == 0 else a
    return out


def _cat(parts: list[jax.Array]) -> jax.Array:
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


@dataclass
class SearchExecutor:
    """Batched staged search over a FaTRQIndex."""

    index: "FaTRQIndex"              # noqa: F821 - import cycle via pipeline
    front: FrontStage
    backend: RefineBackend
    micro_batch: int | None = None   # queries per device step; None = all
    refine_budget: int | None = None  # plan-level SSD budget override

    # -- construction -----------------------------------------------------

    @classmethod
    def from_index(cls, index, *, front: str = "ivf",
                   backend: str = "reference",
                   micro_batch: int | None = None,
                   refine_budget: int | None = None,
                   graph_index: graph_mod.GraphIndex | None = None,
                   layout: str = "static",
                   **front_opts) -> "SearchExecutor":
        if graph_index is not None:
            front_opts["graph_index"] = graph_index
        fs = registry.make_front(front, layout, index, **front_opts)
        be = registry.make_backend(backend)
        return cls(index=index, front=fs, backend=be,
                   micro_batch=micro_batch, refine_budget=refine_budget)

    # -- search -----------------------------------------------------------

    def _chunks(self, queries: jax.Array):
        return iter_chunks(queries, self.micro_batch)

    def _refine_rerank(self, chunk: jax.Array, cand, *, k: int, budget: int,
                       front_span=None
                       ) -> tuple[jax.Array, jax.Array, Counters]:
        """Refine + SSD rerank over a front-stage result: the shared tail
        of ``execute`` and ``run_finish``.

        When tracing is active the refine/rerank spans block on their
        device results before closing (so wall times cover the device
        work, not just the async enqueue) and this chunk's counters are
        folded a second time to attach modeled per-stage seconds + the
        wall/model drift ratio to the spans (``_attach_model``).  Both
        are gated on ``trace.active()`` — disabled runs keep the async
        single-transfer path and bit-identical results."""
        cfg = self.index.config
        tr = trace.active()
        hot = cold = None
        rcand = cand
        if cand.tier is not None:
            # tiered layout: hot candidates detour to exact HBM scoring
            # (masked OUT of refinement), cold candidates ride the normal
            # refine path but are marked so their residual stream re-bills
            # at SSD rates via the is_delta per-level split.  With every
            # row warm both masks are all-False and each op below is an
            # identity — bit-identical to the static layout.
            hot = cand.valid & (cand.tier == TIER_HOT)
            cold = cand.valid & (cand.tier == TIER_COLD)
            rcand = cand._replace(valid=cand.valid & ~hot,
                                  d0=jnp.where(hot, jnp.inf, cand.d0),
                                  is_delta=cold, tier=None)
        with trace.span("refine", track="query",
                        backend=self.backend.name) as sp_refine:
            refined = self.backend.refine(chunk, rcand, self.index.trq,
                                          k=k, bound=cfg.bound, z=cfg.z)
            if tr is not None:
                jax.block_until_ready(refined.est)
        with trace.span("rerank", track="query", budget=budget) as sp_rerank:
            if hot is not None:
                d_hot = stages_mod._score_hot(self.index.x, chunk, cand.ids,
                                              hot)
                est = jnp.where(hot, d_hot, refined.est)
                alive = refined.alive | hot
                topk, topk_d, n_ssd, _ = stages_mod._rerank_survivors_tiered(
                    self.index.x, chunk, cand.ids, est, alive, hot,
                    k=k, budget=budget)
            else:
                topk, topk_d, n_ssd = stages_mod._rerank_survivors(
                    self.index.x, chunk, cand.ids, refined.est,
                    refined.alive, k=k, budget=budget)
            if tr is not None:
                jax.block_until_ready(topk)
        counters = dict(cand.counters)
        _accumulate(counters, refined.counters)
        _accumulate(counters, {"ssd_fetch": n_ssd})
        if tr is not None:
            self._attach_model(tr, {"front": front_span, "refine": sp_refine,
                                    "rerank": sp_rerank}, counters)
        return topk, topk_d, counters

    def _attach_model(self, tr, spans: dict, counters: Counters) -> None:
        """Tracing-only: fold THIS chunk's counters into a throwaway
        ledger and attach per-stage modeled seconds (front → HBM,
        refine incl. handoff/delta → CXL, rerank → SSD) plus the
        measured-wall / modeled drift ratio to the stage spans; observe
        the drift into the ``fatrq_model_drift_ratio{stage=...}``
        histogram.  Also emits one ``refine.l{lv}`` event per TRQ level
        with that level's entering/delta candidate counts and modeled
        CXL time — the per-level view the folded ledger flattens away.

        Costs one extra device→host transfer per chunk; only runs when
        a tracer is active."""
        counts = _collect(counters)
        cost = fold_counts(counts, cost=None, config=self.index.config,
                           layout=self.index.layout,
                           front_fold=self.front.fold_cost)
        model_s = {"front": cost.tier_seconds(Tier.HBM),
                   "refine": cost.tier_seconds(Tier.CXL),
                   "rerank": cost.tier_seconds(Tier.SSD)}
        drift = metrics.active().histogram(
            "fatrq_model_drift_ratio",
            "measured wall seconds / QueryCost-modeled seconds per stage",
            labelnames=("stage",), buckets=_DRIFT_BUCKETS)
        for stage, handle in spans.items():
            if handle is None or handle.span is None:
                continue
            m = model_s[stage]
            handle.set_attr("model_s", m)
            wall = handle.span.wall_s
            if wall is not None and m > 0:
                ratio = wall / m
                handle.set_attr("wall_model_drift", ratio)
                drift.labels(stage=stage).observe(ratio)
        # per-level refine annotation, mirroring fold_counts' level walk:
        # level 0 streams every candidate, level ℓ ≥ 1 only survivors
        sp_refine = spans.get("refine")
        parent = (sp_refine.span.sid
                  if sp_refine is not None and sp_refine.span is not None
                  else None)
        cxl = cost.model[Tier.CXL]
        far = self.index.layout.far_bytes
        n_alive = counts.get("refine_alive", 0)
        for lv in range(self.index.config.trq_levels):
            if lv == 0:
                n_lv = counts.get("front_cand", 0)
                n_lv_delta = counts.get("delta_cand", 0)
            else:
                n_lv = counts.get(f"refine_alive_l{lv}", n_alive)
                n_lv_delta = counts.get(f"refine_alive_l{lv}_delta", 0)
            tr.event(f"refine.l{lv}", track="query", parent=parent,
                     level=lv, entering=int(n_lv), delta=int(n_lv_delta),
                     model_s=cxl.seconds(n_lv, n_lv * far))

    def execute(self, queries: jax.Array, *, k: int | None = None,
                cost: QueryCost | None = None, pad: bool = False
                ) -> tuple[jax.Array, jax.Array, QueryCost]:
        """FaTRQ search: (Q, k) ids, (Q, k) exact squared-L2 distances,
        and the folded traffic ledger.

        ``pad=True`` pads every ragged chunk to its power-of-two bucket
        (``bucket_for``) with a per-query validity mask, so variable batch
        sizes reuse a fixed set of compiled shapes; padded rows produce no
        candidates and no counters, keeping results AND ledger
        bit-identical to the unpadded path."""
        cfg = self.index.config
        k = k or cfg.final_k
        budget = search_budget(cfg, k, self.refine_budget)
        tr = trace.active()

        with trace.span("execute", track="query", front=self.front.name,
                        backend=self.backend.name, k=k, budget=budget,
                        n_queries=int(queries.shape[0])) as sp_ex:
            topk_parts: list[jax.Array] = []
            dist_parts: list[jax.Array] = []
            counters: Counters = {}
            for chunk in self._chunks(queries):
                n = chunk.shape[0]
                if pad:
                    chunk, qvalid = pad_chunk(
                        chunk, bucket_for(n, self.micro_batch))
                else:
                    qvalid = None
                with trace.span("front", track="query",
                                stage=self.front.name, n=n) as sp_front:
                    cand = self.front.candidates(chunk, qvalid=qvalid)
                    if tr is not None:
                        jax.block_until_ready(cand.d0)
                topk, topk_d, cnt = self._refine_rerank(
                    chunk, cand, k=k, budget=budget, front_span=sp_front)
                if topk.shape[0] != n:             # drop padded rows
                    topk, topk_d = topk[:n], topk_d[:n]
                topk_parts.append(topk)
                dist_parts.append(topk_d)
                _accumulate(counters, cnt)

            cost = self._fold(counters, cost)
            if tr is not None:
                _attach_ledger(sp_ex, cost)
        return _cat(topk_parts), _cat(dist_parts), cost

    # -- staged surface (serving engine's double-buffered dispatch) -------

    def run_front(self, chunk: jax.Array, *,
                  qvalid: jax.Array | None = None):
        """Front stage only, for ONE micro-batch (no chunking): candidate
        generation is enqueued on the device and returned as a
        ``Candidates`` handle.  The serving engine issues this for batch
        N+1 while batch N's ``run_finish`` (refine + rerank) drains —
        JAX's async dispatch overlaps the two stages on device.  With a
        tracer active the span blocks on the result (observer effect:
        traced wall times are honest per-stage, at the price of the
        device-side overlap; the virtual-clock pipeline model is
        unaffected)."""
        tr = trace.active()
        with trace.span("front", track="query", stage=self.front.name,
                        n=int(chunk.shape[0]), split=True) as sp:
            cand = self.front.candidates(chunk, qvalid=qvalid)
            if tr is not None:
                jax.block_until_ready(cand.d0)
        if tr is not None:
            # split dispatch never reaches _attach_model with this span
            # (run_finish folds a different chunk's handle), so attribute
            # the front model time here from the front counters alone
            counts = _collect(dict(cand.counters))
            cost = QueryCost()
            self.front.fold_cost(cost, counts, self.index.layout)
            m = cost.tier_seconds(Tier.HBM)
            sp.set_attr("model_s", m)
            if sp.span.wall_s is not None and m > 0:
                ratio = sp.span.wall_s / m
                sp.set_attr("wall_model_drift", ratio)
                metrics.active().histogram(
                    "fatrq_model_drift_ratio",
                    "measured wall seconds / QueryCost-modeled seconds "
                    "per stage",
                    labelnames=("stage",),
                    buckets=_DRIFT_BUCKETS).labels(stage="front") \
                    .observe(ratio)
        return cand

    def run_finish(self, chunk: jax.Array, cand, *, k: int | None = None,
                   cost: QueryCost | None = None
                   ) -> tuple[jax.Array, jax.Array, QueryCost]:
        """Refine + rerank + ledger fold for a ``run_front`` result.
        Together with ``run_front`` this is exactly ``execute`` on one
        chunk — same stages, same counters, same fold — so split dispatch
        stays bit-identical to the monolithic call."""
        cfg = self.index.config
        k = k or cfg.final_k
        budget = search_budget(cfg, k, self.refine_budget)
        tr = trace.active()
        with trace.span("finish", track="query", backend=self.backend.name,
                        k=k, budget=budget) as sp_fin:
            topk, topk_d, counters = self._refine_rerank(chunk, cand, k=k,
                                                         budget=budget)
            cost = self._fold(counters, cost)
            if tr is not None:
                _attach_ledger(sp_fin, cost)
        return topk, topk_d, cost

    def search(self, queries: jax.Array, *, k: int | None = None,
               cost: QueryCost | None = None) -> tuple[jax.Array, QueryCost]:
        """Legacy tuple surface: (Q, k) ids + ledger (no distances)."""
        ids, _, cost = self.execute(queries, k=k, cost=cost)
        return ids, cost

    def execute_baseline(self, queries: jax.Array, *, k: int | None = None,
                         pad: bool = False
                         ) -> tuple[jax.Array, jax.Array, QueryCost]:
        """SoTA baseline (cuVS/FAISS style): front stage, then exact rerank
        of the FULL candidate list from SSD — no far-memory refinement."""
        cfg = self.index.config
        k = k or cfg.final_k
        tr = trace.active()
        with trace.span("execute", track="query", front=self.front.name,
                        backend="baseline", k=k,
                        n_queries=int(queries.shape[0])) as sp_ex:
            topk_parts: list[jax.Array] = []
            dist_parts: list[jax.Array] = []
            counters: Counters = {}
            for chunk in self._chunks(queries):
                n = chunk.shape[0]
                if pad:
                    chunk, qvalid = pad_chunk(
                        chunk, bucket_for(n, self.micro_batch))
                else:
                    qvalid = None
                with trace.span("front", track="query",
                                stage=self.front.name, n=n):
                    cand = self.front.candidates(chunk, qvalid=qvalid)
                    if tr is not None:
                        jax.block_until_ready(cand.d0)
                with trace.span("rerank", track="query", baseline=True):
                    topk, topk_d, n_valid = stages_mod._rerank_all(
                        self.index.x, chunk, cand.ids, cand.valid, k=k)
                    if tr is not None:
                        jax.block_until_ready(topk)
                if topk.shape[0] != n:             # drop padded rows
                    topk, topk_d = topk[:n], topk_d[:n]
                topk_parts.append(topk)
                dist_parts.append(topk_d)
                _accumulate(counters, cand.counters)
                _accumulate(counters, {"ssd_fetch": n_valid})

            counts = _collect(counters)
            cost = QueryCost()
            lay = self.index.layout
            self.front.fold_cost(cost, counts, lay)
            cost.record("rerank", Tier.SSD, counts["ssd_fetch"],
                        lay.ssd_bytes)
            cost.add_compute(_COMPUTE_S_PER_CAND * counts["front_cand"])
            if tr is not None:
                _attach_ledger(sp_ex, cost)
        return _cat(topk_parts), _cat(dist_parts), cost

    def search_baseline(self, queries: jax.Array, *, k: int | None = None
                        ) -> tuple[jax.Array, QueryCost]:
        """Legacy tuple surface over ``execute_baseline``."""
        ids, _, cost = self.execute_baseline(queries, k=k)
        return ids, cost

    # -- cost folding -----------------------------------------------------

    def _fold(self, counters: Counters, cost: QueryCost | None) -> QueryCost:
        """One host transfer: device counters → Table-I traffic ledger.
        The tiered layout's per-list access histogram rides the same
        transfer and feeds the index's heat tracker here — heat tracking
        costs no extra device round-trips."""
        counts = _collect(counters)
        heat = counts.pop("list_heat", None)
        if heat is not None:
            observe = getattr(self.index, "observe_heat", None)
            if observe is not None:
                observe(heat)
        return fold_counts(counts, cost=cost, config=self.index.config,
                           layout=self.index.layout,
                           front_fold=self.front.fold_cost)


def _attach_ledger(handle, cost: QueryCost) -> None:
    """Attach the folded Table-I ledger + modeled breakdown to a span.

    Note the ledger reflects the ``cost`` object AFTER the fold — when a
    caller threads a running ``cost=`` across calls (serving batch
    totals) the attrs carry the cumulative state, matching what the
    caller receives."""
    handle.set_attrs(
        ledger={key: [t.accesses, t.bytes]
                for key, t in sorted(cost.ledger.items())},
        model_breakdown_s=cost.breakdown(),
        model_total_s=cost.total_seconds())


def fold_counts(counts: dict[str, int], *, cost: QueryCost | None, config,
                layout, front_fold) -> QueryCost:
    """Fold collected stage counters into a Table-I traffic ledger.

    Shared between the unsharded ``SearchExecutor`` and the per-shard fold
    in ``anns.sharding`` (which builds one ledger per shard from the same
    counter names, then combines them with ``QueryCost.merge_parallel``).
    """
    cost = cost or QueryCost()
    n_cand = counts["front_cand"]
    n_alive = counts["refine_alive"]
    # tiered layout (anns.tiered): hot candidates score exactly against
    # HBM-resident full vectors and never touch far memory; cold
    # candidates' residual stream re-bills at SSD rates.  The tiered
    # front ALWAYS emits both counters (zero-valued when all-warm), and
    # no other front emits them — "tiered" and "streaming" marking are
    # mutually exclusive, so the per-level marked share below is
    # unambiguous.
    tiered = "cold_cand" in counts
    n_hot = counts.get("hot_cand", 0)
    n_cold = counts.get("cold_cand", 0)

    front_fold(cost, counts, layout)
    # front → refine handoff: 4 B coarse distance per candidate (§IV);
    # hot candidates stay on device, so nothing crosses for them
    cost.record("handoff", Tier.CXL, n_cand - n_hot, 4)
    if n_hot:
        cost.record("hot", Tier.HBM, n_hot, layout.ssd_bytes)
    # level-0 codes stream from far memory for ALL candidates; level
    # ℓ ≥ 1 only for survivors of level ℓ−1.  The backends emit the
    # actual per-level entering counts (``refine_alive_l{ℓ}``); the
    # final-survivor count is only a fallback for legacy counter dicts
    # that predate per-level counters (it UNDER-charges levels 1..L−1,
    # since the mask chain is monotonically shrinking).
    # Candidates that came off delta pages (streaming subsystem, counter
    # ``delta_cand``) stream the SAME far-memory bytes but are billed to a
    # DISTINCT ledger entry so delta-list traffic stays visible; static
    # indexes never emit the counters and their ledgers are unchanged.
    # The split covers EVERY level of the stream: level 0 via
    # ``delta_cand`` (all candidates), levels ℓ ≥ 1 via the per-level
    # delta survivor counters (``refine_alive_l{ℓ}_delta``) both refine
    # backends emit whenever the front marks delta candidates.
    # On the tiered layout the refine backends see cold candidates via the
    # SAME is_delta marking mechanism, so ``refine_alive_l{ℓ}_delta`` is
    # the cold-entering share there and re-bills to ``cold:ssd``.
    n_delta = counts.get("delta_cand", 0)
    cost.record("refine", Tier.CXL, n_cand - n_delta - n_hot - n_cold,
                layout.far_bytes)
    if n_delta:
        cost.record("delta", Tier.CXL, n_delta, layout.far_bytes)
    if n_cold:
        cost.record("cold", Tier.SSD, n_cold, layout.far_bytes)
    for lv in range(1, config.trq_levels):
        n_lv = counts.get(f"refine_alive_l{lv}", n_alive)
        n_lv_mark = counts.get(f"refine_alive_l{lv}_delta", 0)
        cost.record("refine", Tier.CXL, n_lv - n_lv_mark, layout.far_bytes)
        if n_lv_mark:
            if tiered:
                cost.record("cold", Tier.SSD, n_lv_mark, layout.far_bytes)
            else:
                cost.record("delta", Tier.CXL, n_lv_mark, layout.far_bytes)
    # survivors (≤ budget per query) hit SSD
    cost.record("rerank", Tier.SSD, counts["ssd_fetch"], layout.ssd_bytes)
    cost.add_compute(_COMPUTE_S_PER_CAND * n_cand)
    return cost


# -------------------------------------------------------- executor caching
# Caches live ON the index instance (plain attributes), so their lifetime is
# exactly the index's lifetime — the resulting index↔executor reference
# cycle is ordinary gc fodder, with no process-global registry to leak.
# (The kNN-graph cache moved to ``stages.graph_for`` with the front
# factories; ``_graph_for`` stays importable from here.)


def make_executor(index, *, front: str = "ivf", backend: str = "reference",
                  micro_batch: int | None = None,
                  refine_budget: int | None = None, layout: str = "static",
                  **front_opts) -> SearchExecutor:
    """Memoized executor factory — facade entry point.

    Executors are cached per (generation, front, backend, micro_batch,
    refine_budget, layout) so the compatibility wrappers in
    ``anns.pipeline`` and the serving layer can call this on every request
    without rebuilding stages.  The generation component makes migration
    visible: after a ``TieredIndex.rebalance_tiers()`` the old executors'
    front stages hold superseded placement arrays, so stale-generation
    entries are pruned and a fresh executor is built (static indexes have
    no generation and keep the behavior they always had).
    """
    gen = getattr(index, "generation", 0)
    key = (gen, front, backend, micro_batch, refine_budget, layout,
           tuple(sorted(front_opts.items())))
    cache = getattr(index, "_executor_cache", None)
    if cache is None:
        cache = {}
        index._executor_cache = cache
    ex = cache.get(key)
    if ex is None:
        ex = SearchExecutor.from_index(index, front=front, backend=backend,
                                       micro_batch=micro_batch,
                                       refine_budget=refine_budget,
                                       layout=layout, **front_opts)
        for kk in [kk for kk in cache if kk[0] != gen]:
            del cache[kk]
        cache[key] = ex
    return ex
