"""FaTRQ ANNS pipeline — compatibility facade over the staged executor.

Since the staged-executor refactor the search datapath lives in
``anns/stages.py`` (the pluggable front / refine / rerank stages) and
``anns/executor.py`` (the ``SearchExecutor`` that runs them fully batched
and folds device-side stage counters into a ``memory.QueryCost`` ledger).
This module keeps the original public API stable:

  * ``PipelineConfig`` / ``FaTRQIndex`` / ``build`` — offline index build
    (PQ → IVF → TRQ encode → index-driven calibration, unchanged).
  * ``search`` — FaTRQ staged search; accepts ``front=`` ("ivf" |
    "graph") and ``backend=`` ("reference" | "pallas") to select the
    candidate generator and the refinement datapath, defaulting to the
    config's settings.  Both backends produce identical top-k ids; "pallas"
    runs the fused ``kernels.ternary_refine`` batched kernel.  Since the
    query-planning refactor this is a shim over ``anns.api.Database`` —
    new code should use ``Database.query`` directly, which also returns
    the exact top-k distances and the resolved ``QueryPlan``.
  * ``baseline_search`` — coarse ADC + full SSD rerank (cuVS/FAISS-style
    comparison point), also ``Database``-backed.
  * ``recall_at_k`` — evaluation helper.

See ``docs/architecture.md`` for the stage pipeline, backend selection,
and QueryCost flow.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import trq as trq_mod
from repro.core.trq import TRQCodes
from repro.index import ivf as ivf_mod
from repro.memory import QueryCost, RecordLayout
from repro.quant import pq as pq_mod


@dataclass(frozen=True)
class PipelineConfig:
    dim: int = 128
    pq_m: int = 16
    pq_k: int = 256
    nlist: int = 64
    nprobe: int = 8
    trq_levels: int = 1
    final_k: int = 10
    refine_budget: int | None = None   # max SSD fetches; None → k (tightest)
    bound: str = "cauchy"              # "cauchy" | "quantile"
    z: float = 3.0
    calib_fraction: float = 0.003      # §III-E: ~0.3%
    calib_pairs_per_sample: int = 8
    front: str = "ivf"                 # default front stage for search()
    backend: str = "reference"         # default refinement backend
    micro_batch: int | None = None     # queries per device step; None = all


@dataclass(eq=False)
class FaTRQIndex:
    config: PipelineConfig
    codebook: pq_mod.PQCodebook
    pq_codes: jax.Array          # (N, M) uint8 — fast memory
    ivf: ivf_mod.IVFIndex
    trq: TRQCodes                # packed codes + scalars — far memory
    x: jax.Array                 # (N, D) full precision — "SSD"
    layout: RecordLayout = field(init=False)

    def __post_init__(self):
        self.layout = RecordLayout(dim=self.config.dim, pq_m=self.config.pq_m,
                                   levels=self.config.trq_levels,
                                   store_rho=(self.config.bound == "cauchy"))


def build(key: jax.Array, x: jax.Array, config: PipelineConfig) -> FaTRQIndex:
    """Offline build: PQ → IVF → TRQ encode → index-driven calibration."""
    k_pq, k_ivf, k_cal, k_calq = jax.random.split(key, 4)
    n = x.shape[0]

    codebook = pq_mod.train(k_pq, x, config.pq_m, config.pq_k)
    pq_codes = pq_mod.encode(codebook, x)
    x_c = pq_mod.decode(codebook, pq_codes)

    ivf = ivf_mod.build(k_ivf, x, config.nlist)
    trq, _ = trq_mod.encode_database(x, x_c, num_levels=config.trq_levels)

    # Calibration pairs from the index itself (§III-E): sample records, pair
    # each with members of its own inverted list (its local boundary).
    n_samples = max(int(config.calib_fraction * n), 32)
    samp = jax.random.choice(k_cal, n, (n_samples,), replace=False)
    list_ids = np.asarray(ivf_mod.assign_lists(ivf, x[samp]))
    pairs_q, pairs_i = [], []
    lists_np = np.asarray(ivf.lists)
    lens_np = np.asarray(ivf.list_len)
    rng = np.random.default_rng(0)
    for s, li in zip(np.asarray(samp), list_ids):
        members = lists_np[li, :max(lens_np[li], 1)]
        members = members[(members >= 0) & (members != s)]  # no self-pairs
        if members.size == 0:
            continue
        take = rng.choice(members, size=min(config.calib_pairs_per_sample,
                                            members.size), replace=False)
        for t in take:
            pairs_q.append(s)
            pairs_i.append(t)
    pair_q_idx = jnp.asarray(pairs_q)
    pair_idx = jnp.asarray(pairs_i)
    # queries for calibration = sampled records themselves (they sit on each
    # other's boundaries) with slight perturbation to avoid d=0 degeneracy
    qs = x[pair_q_idx] + 0.01 * jax.random.normal(k_calq,
                                                  x[pair_q_idx].shape)
    trq = trq_mod.calibrate(trq, qs, x, x_c, pair_idx)

    return FaTRQIndex(config=config, codebook=codebook, pq_codes=pq_codes,
                      ivf=ivf, trq=trq, x=x)


# ----------------------------------------------------------------- search


def search(index: FaTRQIndex, queries: jax.Array, *, k: int | None = None,
           cost: QueryCost | None = None, front: str | None = None,
           backend: str | None = None, shards: int | None = None,
           micro_batch: int | None = None, mesh=None
           ) -> tuple[jax.Array, QueryCost]:
    """Batched FaTRQ search; returns (Q, k) ids + the traffic ledger.

    Compatibility shim over ``anns.api``: the kwargs become a ``QueryPlan``
    and the call routes through ``Database.wrap(index).query`` — one
    capability-validated dispatch over static / sharded / streaming
    layouts, with the plan-keyed executor cache behind it.  Use the
    ``Database`` API directly to also get the exact top-k distances
    (``SearchResult.distances``) this shim drops.

    ``front`` / ``backend`` / ``micro_batch`` override the config's stage
    selection for this call (e.g. ``backend="pallas"`` routes refinement
    through the fused Pallas kernel).  ``shards`` > 1 routes the call
    through the sharded subsystem (``anns.sharding``); ``index`` may also
    be a ``StreamingIndex`` or ``ShardedIndex``.  Both registered fronts
    (IVF and graph) run on every layout; invalid plans — unknown names, a
    shard count mismatching a wrapped ``ShardedIndex``, baseline mode off
    the static layout — raise ``api.PlanError`` at plan time.
    """
    from repro.anns.api import Database, QueryPlan
    res = Database.wrap(index).query(
        queries,
        plan=QueryPlan(front=front, backend=backend, shards=shards, k=k,
                       micro_batch=micro_batch),
        cost=cost, mesh=mesh)
    return res.ids, res.cost


def baseline_search(index: FaTRQIndex, queries: jax.Array, *,
                    k: int | None = None, front: str | None = None
                    ) -> tuple[jax.Array, QueryCost]:
    """SoTA baseline (cuVS/FAISS style): coarse ADC then rerank the FULL
    candidate list from SSD — no far-memory refinement.  Shim over
    ``anns.api`` (``QueryPlan(mode="baseline")``)."""
    from repro.anns.api import Database, QueryPlan
    res = Database.wrap(index).query(
        queries, plan=QueryPlan(front=front, k=k, mode="baseline"))
    return res.ids, res.cost


def recall_at_k(pred: jax.Array, gt: jax.Array, k: int) -> float:
    """recall@k with gt (Q, ≥k).

    Vectorized set-intersection: a broadcast membership test replaces the
    per-row Python ``set`` loop.  ``first`` keeps only the first occurrence
    of a repeated prediction so duplicate ids still count once, exactly the
    old ``len(set(p) & set(g))`` semantics (the ``any`` over gt already
    dedups that side).
    """
    p = np.asarray(pred)[:, :k]
    g = np.asarray(gt)[:, :k]
    kk = p.shape[1]
    hit = (p[:, :, None] == g[:, None, :]).any(axis=2)        # (Q, kk)
    first = ~((p[:, :, None] == p[:, None, :])
              & np.tril(np.ones((kk, kk), bool), -1)[None]).any(axis=2)
    return float((hit & first).sum()) / (p.shape[0] * k)
