"""FaTRQ-augmented ANNS pipeline (paper Fig. 5).

Stages (per query batch):
  1. front stage  : IVF probe (or graph beam) + PQ-ADC coarse distances —
                    fast-memory traffic (HBM on the accelerator, DRAM on CPU).
  2. FaTRQ refine : stream packed ternary codes + scalars from FAR memory,
                    progressive estimate, batched level-wise pruning.
  3. final rerank : only survivors fetch full-precision vectors ("SSD"),
                    exact L2, top-k.

Every stage records traffic in a memory.QueryCost ledger; benchmarks turn
ledgers into throughput via the Table-I tier model.  The baseline pipeline
(no FaTRQ) reranks the whole candidate list from SSD — the paper's cuVS/
FAISS comparison point.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import trq as trq_mod
from repro.core.trq import TRQCodes
from repro.index import ivf as ivf_mod
from repro.memory import QueryCost, RecordLayout, Tier
from repro.quant import pq as pq_mod


@dataclass(frozen=True)
class PipelineConfig:
    dim: int = 128
    pq_m: int = 16
    pq_k: int = 256
    nlist: int = 64
    nprobe: int = 8
    trq_levels: int = 1
    final_k: int = 10
    refine_budget: int | None = None   # max SSD fetches; None → k (tightest)
    bound: str = "cauchy"              # "cauchy" | "quantile"
    z: float = 3.0
    calib_fraction: float = 0.003      # §III-E: ~0.3%
    calib_pairs_per_sample: int = 8


@dataclass
class FaTRQIndex:
    config: PipelineConfig
    codebook: pq_mod.PQCodebook
    pq_codes: jax.Array          # (N, M) uint8 — fast memory
    ivf: ivf_mod.IVFIndex
    trq: TRQCodes                # packed codes + scalars — far memory
    x: jax.Array                 # (N, D) full precision — "SSD"
    layout: RecordLayout = field(init=False)

    def __post_init__(self):
        self.layout = RecordLayout(dim=self.config.dim, pq_m=self.config.pq_m,
                                   levels=self.config.trq_levels,
                                   store_rho=(self.config.bound == "cauchy"))


def build(key: jax.Array, x: jax.Array, config: PipelineConfig) -> FaTRQIndex:
    """Offline build: PQ → IVF → TRQ encode → index-driven calibration."""
    k_pq, k_ivf, k_cal, k_calq = jax.random.split(key, 4)
    n = x.shape[0]

    codebook = pq_mod.train(k_pq, x, config.pq_m, config.pq_k)
    pq_codes = pq_mod.encode(codebook, x)
    x_c = pq_mod.decode(codebook, pq_codes)

    ivf = ivf_mod.build(k_ivf, x, config.nlist)
    trq, _ = trq_mod.encode_database(x, x_c, num_levels=config.trq_levels)

    # Calibration pairs from the index itself (§III-E): sample records, pair
    # each with members of its own inverted list (its local boundary).
    n_samples = max(int(config.calib_fraction * n), 32)
    samp = jax.random.choice(k_cal, n, (n_samples,), replace=False)
    list_ids = np.asarray(ivf_mod.assign_lists(ivf, x[samp]))
    pairs_q, pairs_i = [], []
    lists_np = np.asarray(ivf.lists)
    lens_np = np.asarray(ivf.list_len)
    rng = np.random.default_rng(0)
    for s, li in zip(np.asarray(samp), list_ids):
        members = lists_np[li, :max(lens_np[li], 1)]
        members = members[(members >= 0) & (members != s)]  # no self-pairs
        if members.size == 0:
            continue
        take = rng.choice(members, size=min(config.calib_pairs_per_sample,
                                            members.size), replace=False)
        for t in take:
            pairs_q.append(s)
            pairs_i.append(t)
    pair_q_idx = jnp.asarray(pairs_q)
    pair_idx = jnp.asarray(pairs_i)
    # queries for calibration = sampled records themselves (they sit on each
    # other's boundaries) with slight perturbation to avoid d=0 degeneracy
    qs = x[pair_q_idx] + 0.01 * jax.random.normal(k_calq,
                                                  x[pair_q_idx].shape)
    trq = trq_mod.calibrate(trq, qs, x, x_c, pair_idx)

    return FaTRQIndex(config=config, codebook=codebook, pq_codes=pq_codes,
                      ivf=ivf, trq=trq, x=x)


# ----------------------------------------------------------------- search


@partial(jax.jit, static_argnames=("nprobe", "k", "bound", "z", "budget"))
def _search_one(q, codebook, pq_codes, ivf, trq, x, *, nprobe, k, bound, z,
                budget):
    """Device part of one query: returns (topk_ids, n_cand, n_alive, n_ssd)."""
    cand = ivf_mod.probe(ivf, q, nprobe=nprobe)               # (C,) w/ -1
    valid = cand >= 0
    safe = jnp.maximum(cand, 0)

    table = pq_mod.adc_table(codebook, q)
    d0 = pq_mod.adc_distances(table, pq_codes[safe])
    d0 = jnp.where(valid, d0, jnp.inf)

    state = trq_mod.progressive_search(q, d0, trq, safe, k=k, bound=bound,
                                       z=z)
    alive = state.alive & valid

    # survivors ranked by refined estimate; cap SSD fetches at `budget`
    est = jnp.where(alive, state.est, jnp.inf)
    _, order = jax.lax.top_k(-est, budget)
    fetch_ids = safe[order]
    fetch_alive = alive[order]
    d_exact = jnp.sum((x[fetch_ids] - q[None]) ** 2, axis=-1)
    d_exact = jnp.where(fetch_alive, d_exact, jnp.inf)
    _, best = jax.lax.top_k(-d_exact, k)
    topk = fetch_ids[best]
    return (topk, jnp.sum(valid), jnp.sum(alive),
            jnp.minimum(jnp.sum(fetch_alive), budget))


def search(index: FaTRQIndex, queries: jax.Array, *, k: int | None = None,
           cost: QueryCost | None = None) -> tuple[jax.Array, QueryCost]:
    """Batched FaTRQ search; returns (Q, k) ids + the traffic ledger."""
    cfg = index.config
    k = k or cfg.final_k
    budget = cfg.refine_budget or max(4 * k, 32)
    run = jax.vmap(lambda q: _search_one(
        q, index.codebook, index.pq_codes, index.ivf, index.trq, index.x,
        nprobe=cfg.nprobe, k=k, bound=cfg.bound, z=cfg.z, budget=budget))
    topk, n_cand, n_alive, n_ssd = run(queries)

    cost = cost or QueryCost()
    lay = index.layout
    total_cand = int(jnp.sum(n_cand))
    total_alive = int(jnp.sum(n_alive))
    total_ssd = int(jnp.sum(n_ssd))
    nq = queries.shape[0]
    # stage 1: PQ codes + LUT from fast memory; 4B coarse distance handoff
    cost.record("coarse", Tier.HBM, total_cand, lay.fast_bytes)
    cost.record("handoff", Tier.CXL, total_cand, 4)
    # stage 2: ALL candidates stream level-0 codes from far memory;
    # deeper levels only for survivors of the previous level.
    cost.record("refine", Tier.CXL, total_cand, lay.far_bytes)
    for lv in range(1, cfg.trq_levels):
        cost.record("refine", Tier.CXL, total_alive, lay.far_bytes)
    # stage 3: survivors (≤ budget) hit SSD
    cost.record("rerank", Tier.SSD, total_ssd, lay.ssd_bytes)
    cost.add_compute(1e-7 * total_cand)   # ADC+ternary adds (measured scale)
    return topk, cost


def baseline_search(index: FaTRQIndex, queries: jax.Array, *,
                    k: int | None = None) -> tuple[jax.Array, QueryCost]:
    """SoTA baseline (cuVS/FAISS style): coarse ADC then rerank the FULL
    candidate list from SSD — no far-memory refinement."""
    cfg = index.config
    k = k or cfg.final_k

    @jax.jit
    def one(q):
        cand = ivf_mod.probe(index.ivf, q, nprobe=cfg.nprobe)
        valid = cand >= 0
        safe = jnp.maximum(cand, 0)
        d = jnp.sum((index.x[safe] - q[None]) ** 2, axis=-1)
        d = jnp.where(valid, d, jnp.inf)
        _, best = jax.lax.top_k(-d, k)
        return safe[best], jnp.sum(valid)

    topk, n_cand = jax.vmap(one)(queries)
    cost = QueryCost()
    lay = index.layout
    total = int(jnp.sum(n_cand))
    cost.record("coarse", Tier.HBM, total, lay.fast_bytes)
    cost.record("rerank", Tier.SSD, total, lay.ssd_bytes)
    cost.add_compute(1e-7 * total)
    return topk, cost


def recall_at_k(pred: jax.Array, gt: jax.Array, k: int) -> float:
    """recall@k with gt (Q, ≥k)."""
    hits = 0
    p = np.asarray(pred)[:, :k]
    g = np.asarray(gt)[:, :k]
    for i in range(p.shape[0]):
        hits += len(set(p[i].tolist()) & set(g[i].tolist()))
    return hits / (p.shape[0] * k)
