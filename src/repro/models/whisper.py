"""Whisper-style encoder–decoder (whisper-medium backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, T_frames, D) — here the encoder
consumes them directly (sinusoidal positions added).  Decoder: causal
self-attention + cross-attention into the encoder output + GELU MLP,
learned positions (whisper uses MHA: n_kv_heads == n_heads).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.scan_util import scan_layers
from repro.models.layers import rms_norm


def _mlp_params(key, d, f, dtype):
    k1, k2 = jax.random.split(key)
    return {"wi": L.dense_init(k1, (d, f), dtype),
            "wo": L.dense_init(k2, (f, d), dtype)}


def _mlp(x, p):
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]


def init(key, cfg, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    # learned decoder positions; real whisper stops at 448 — extended to
    # cover the assigned 32k decode/prefill shapes (DESIGN.md §4)
    max_dec = 32768 if cfg.vocab > 1000 else 128

    def enc_block(k):
        ka, kf = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), dtype),
                "attn": L.attn_params(ka, cfg, dtype),
                "ln2": jnp.ones((cfg.d_model,), dtype),
                "mlp": _mlp_params(kf, cfg.d_model, cfg.d_ff, dtype)}

    def dec_block(k):
        ka, kx, kf = jax.random.split(k, 3)
        return {"ln1": jnp.ones((cfg.d_model,), dtype),
                "attn": L.attn_params(ka, cfg, dtype),
                "lnx": jnp.ones((cfg.d_model,), dtype),
                "xattn": L.attn_params(kx, cfg, dtype),
                "ln2": jnp.ones((cfg.d_model,), dtype),
                "mlp": _mlp_params(kf, cfg.d_model, cfg.d_ff, dtype)}

    return {
        "enc_pos": L.dense_init(ks[0], (cfg.enc_frames, cfg.d_model), dtype,
                                0.02),
        "enc_blocks": jax.vmap(enc_block)(
            jax.random.split(ks[1], cfg.n_enc_layers)),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "embed": L.dense_init(ks[2], (cfg.vocab, cfg.d_model), dtype, 0.02),
        "dec_pos": L.dense_init(ks[3], (max_dec, cfg.d_model), dtype, 0.02),
        "dec_blocks": jax.vmap(dec_block)(
            jax.random.split(ks[4], cfg.n_layers)),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": L.dense_init(ks[5], (cfg.d_model, cfg.vocab), dtype),
    }


def encode(params, frames, cfg, *, remat=True):
    """frames (B, T_f, D) precomputed frame embeddings (frontend stub)."""
    x = L.constrain_batch(frames + params["enc_pos"][None,
                                                     :frames.shape[1]])

    def body(x, bp):
        def fn(xx, pp):
            h = L.gqa_attention(rms_norm(xx, pp["ln1"], cfg.norm_eps),
                                pp["attn"], cfg, sin=None, cos=None,
                                causal=False)
            xx = xx + h
            return L.constrain_batch(
                xx + _mlp(rms_norm(xx, pp["ln2"], cfg.norm_eps),
                          pp["mlp"]))
        if remat:
            fn = jax.checkpoint(fn)
        return fn(x, bp), None

    x, _ = scan_layers(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


@functools.partial(jax.jit, static_argnames=("cfg", "remat", "last_only"))
def forward(params, frames, tokens, cfg, *, remat=True, last_only=False):
    """Teacher-forced training pass → (logits (B, S, V), aux)."""
    enc = encode(params, frames, cfg, remat=remat)
    s = tokens.shape[1]
    x = L.constrain_batch(params["embed"][tokens]
                          + params["dec_pos"][None, :s])

    def body(x, bp):
        def fn(xx, pp):
            h = L.gqa_attention(rms_norm(xx, pp["ln1"], cfg.norm_eps),
                                pp["attn"], cfg, sin=None, cos=None,
                                causal=True)
            xx = xx + h
            kx, vx = L.project_kv(enc, pp["xattn"], cfg)
            h = L.gqa_attention(rms_norm(xx, pp["lnx"], cfg.norm_eps),
                                pp["xattn"], cfg, sin=None, cos=None,
                                causal=False, kv_override=(kx, vx))
            xx = xx + h
            return L.constrain_batch(
                xx + _mlp(rms_norm(xx, pp["ln2"], cfg.norm_eps),
                          pp["mlp"]))
        if remat:
            fn = jax.checkpoint(fn)
        return fn(x, bp), None

    x, _ = scan_layers(body, x, params["dec_blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    return L.constrain_batch_vocab(x @ params["lm_head"]), \
        jnp.asarray(0.0, jnp.float32)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.float32) -> dict:
    lkv = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    xkv = (cfg.n_layers, batch, cfg.enc_frames, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(lkv, dtype), "v": jnp.zeros(lkv, dtype),
            "xk": jnp.zeros(xkv, dtype), "xv": jnp.zeros(xkv, dtype),
            "len": jnp.zeros((), jnp.int32)}


@functools.partial(jax.jit, static_argnames=("cfg",))
def prefill_encoder(params, frames, cfg, cache: dict) -> dict:
    """Encode audio + precompute per-layer cross-attention K/V."""
    enc = encode(params, frames, cfg, remat=False)

    def body(_, bp):
        return None, L.project_kv(enc, bp["xattn"], cfg)

    _, (xk, xv) = scan_layers(body, None, params["dec_blocks"])
    return {**cache, "xk": xk.astype(cache["xk"].dtype),
            "xv": xv.astype(cache["xv"].dtype)}


@functools.partial(jax.jit, static_argnames=("cfg",))
def decode_step(params, tokens, cache, cfg):
    """One decoder token against self-KV cache + fixed cross-KV."""
    b = tokens.shape[0]
    pos = cache["len"]
    x = params["embed"][tokens] \
        + lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0)[None]

    def body(x, xs):
        bp, ck, cv, xk, xv = xs
        xn = rms_norm(x, bp["ln1"], cfg.norm_eps)
        k_new, v_new = L.project_kv(xn, bp["attn"], cfg)
        ck = lax.dynamic_update_slice_in_dim(ck, k_new.astype(ck.dtype),
                                             pos, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cv, v_new.astype(cv.dtype),
                                             pos, axis=1)
        h = L.gqa_attention(xn, bp["attn"], cfg, sin=None, cos=None,
                            causal=True, offset=pos, kv_len_valid=pos + 1,
                            kv_override=(ck, cv))
        x = x + h
        h = L.gqa_attention(rms_norm(x, bp["lnx"], cfg.norm_eps),
                            bp["xattn"], cfg, sin=None, cos=None,
                            causal=False, kv_override=(xk, xv))
        x = x + h
        x = x + _mlp(rms_norm(x, bp["ln2"], cfg.norm_eps), bp["mlp"])
        return x, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["dec_blocks"], cache["k"],
                                     cache["v"], cache["xk"], cache["xv"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x[:, -1] @ params["lm_head"], {
        "k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
        "len": pos + 1}
