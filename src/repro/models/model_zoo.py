"""Uniform model API over the four model families.

    api = build_model(cfg)
    params = api.init(key, dtype)
    logits, aux = api.forward(params, batch)          # train/prefill path
    cache = api.init_cache(params, batch, max_len, dtype)
    logits, cache = api.decode_step(params, tokens, cache)

`batch` is a dict from data/ or launch/input_specs: tokens/labels for LMs,
+frames for audio, +patch embeds for VLM prefill.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import ssm_lm, transformer, whisper


@dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    init: Callable
    forward: Callable                 # (params, batch) → (logits, aux)
    init_cache: Callable              # (cfg, batch_size, max_len, dtype)
    decode_step: Callable             # (params, tokens, cache) → (logits, cache)
    prefill: Callable | None = None


def build_model(cfg: ArchConfig) -> ModelApi:
    if cfg.enc_dec:
        return ModelApi(
            cfg=cfg,
            init=lambda key, dtype=jnp.float32: whisper.init(key, cfg, dtype),
            forward=lambda p, batch, **kw: whisper.forward(
                p, batch["frames"], batch["tokens"], cfg, **kw),
            init_cache=lambda p, b, s, dtype=jnp.float32:
                whisper.init_cache(cfg, b, s, dtype),
            decode_step=lambda p, t, c: whisper.decode_step(p, t, c, cfg),
            prefill=lambda p, batch, cache: whisper.prefill_encoder(
                p, batch["frames"], cfg, cache),
        )
    if cfg.family == "ssm":
        return ModelApi(
            cfg=cfg,
            init=lambda key, dtype=jnp.float32: ssm_lm.xlstm_init(key, cfg,
                                                                  dtype),
            forward=lambda p, batch, **kw: ssm_lm.xlstm_forward(
                p, batch["tokens"], cfg, **kw),
            init_cache=lambda p, b, s, dtype=jnp.float32:
                ssm_lm.xlstm_init_cache(cfg, b, dtype),
            decode_step=lambda p, t, c: ssm_lm.xlstm_decode_step(p, t, c,
                                                                 cfg),
        )
    if cfg.family == "hybrid":
        return ModelApi(
            cfg=cfg,
            init=lambda key, dtype=jnp.float32: ssm_lm.zamba_init(key, cfg,
                                                                  dtype),
            forward=lambda p, batch, **kw: ssm_lm.zamba_forward(
                p, batch["tokens"], cfg, **kw),
            init_cache=lambda p, b, s, dtype=jnp.float32:
                ssm_lm.zamba_init_cache(cfg, b, s, dtype),
            decode_step=lambda p, t, c: ssm_lm.zamba_decode_step(p, t, c,
                                                                 cfg),
        )
    # dense / moe / vlm → generic transformer
    def fwd(p, batch, **kw):
        embeds = batch.get("embeds")
        positions = batch.get("positions")
        return transformer.forward(p, batch["tokens"], cfg, embeds=embeds,
                                   positions=positions, **kw)

    return ModelApi(
        cfg=cfg,
        init=lambda key, dtype=jnp.float32: transformer.init(key, cfg, dtype),
        forward=fwd,
        init_cache=lambda p, b, s, dtype=jnp.float32:
            transformer.init_cache(cfg, b, s, dtype),
        decode_step=lambda p, t, c: transformer.decode_step(p, t, c, cfg),
        prefill=lambda p, batch, cache: transformer.prefill(
            p, batch["tokens"], cfg, cache,
            embeds=batch.get("embeds"))[1],
    )


def loss_fn(api: ModelApi, params, batch, *, aux_weight: float = 0.01,
            **kw) -> jax.Array:
    """Next-token cross-entropy (+ MoE aux).

    Sharding-aware form: the label logit is extracted with a one-hot
    contraction and normalized with logsumexp — both reduce over the
    (model-sharded) vocab axis without gathering it, so no device ever
    materializes unsharded (B, S, V) logits."""
    logits, aux = api.forward(params, batch, **kw)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(lse - label_logit) + aux_weight * aux
