"""Flash-decoding over a sequence-sharded KV cache (shard_map).

Problem: GQA archs whose KV-head count doesn't divide the TP axis (e.g.
qwen2-72b: 8 KV heads on a 16-way `model` axis) shard the decode cache
along SEQUENCE instead.  Plain einsum attention then makes XLA all-gather
the whole cache every layer (the 18 s collective term in the baseline
roofline).  The fix is the TPU-native form of flash-decoding: each model
shard computes attention over its local S-chunk, and the shards combine
with (max, rescaled-sum) — 3 tiny collectives of (B, H[, hd]) instead of
gathering (B, S, KV, hd).

Math (per head): softmax over the union of chunks
    m_g = pmax(m_i);  num = psum(e^{m_i−m_g}·num_i);  den = psum(e^{m_i−m_g}·den_i)
    out = num / den — exactly softmax(q·Kᵀ)·V, numerically stabilized.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def _local_attn(q, k, v, pos, window, *, shard_axis: str, n_rep: int):
    """One shard's partial attention.
    q (Bl, 1, H, hd) full heads; k/v (Bl, Sl, KV, hd) local chunk."""
    bl, sl, kv, hd = k.shape
    i = lax.axis_index(shard_axis)
    kpos = i * sl + jnp.arange(sl)                      # global positions
    valid = kpos <= pos                                 # causal/cache-len
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        valid &= jnp.where(w > 0, kpos > pos - w, True)

    kr = jnp.repeat(k, n_rep, axis=2)                   # (Bl, Sl, H, hd)
    vr = jnp.repeat(v, n_rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    logits = jnp.where(valid[None, None, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)                        # (Bl, H, 1)
    # all-invalid shard: guard -inf
    m_safe = jnp.where(jnp.isfinite(m), m, -1e30)
    p = jnp.exp(logits - m_safe[..., None])
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    den = jnp.sum(p, axis=-1)                           # (Bl, H, 1)
    num = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), vr)

    g_m = lax.pmax(m_safe, shard_axis)
    scale = jnp.exp(m_safe - g_m)                       # (Bl, H, 1)
    num = lax.psum(num * scale.transpose(0, 2, 1)[..., None]
                   .astype(num.dtype), shard_axis)
    den = lax.psum(den * scale, shard_axis)             # (Bl, H, 1)
    out = num / jnp.maximum(den, 1e-30).transpose(0, 2, 1)[..., None] \
        .astype(num.dtype)
    return out                                          # (Bl, 1, H, hd)


def flash_decode(q, ck, cv, pos, *, mesh, dp_axes: tuple, n_rep: int,
                 window=None, shard_axis: str = "model"):
    """q (B,1,H,hd) replicated over `model`; ck/cv (B,S,KV,hd) sharded
    (dp, model) on (B, S).  Returns (B,1,H,hd) sharded on B only."""
    dp = tuple(dp_axes) if dp_axes else None
    fn = partial(_local_attn, shard_axis=shard_axis, n_rep=n_rep)
    return shard_map(
        lambda qq, kk, vv: fn(qq, kk, vv, pos, window),
        mesh=mesh,
        in_specs=(P(dp, None, None, None), P(dp, shard_axis, None, None),
                  P(dp, shard_axis, None, None)),
        out_specs=P(dp, None, None, None),
    )(q, ck, cv)
