"""Shared model layers: RMSNorm, RoPE / M-RoPE, GQA attention (full /
sliding / cross), SwiGLU.  Pure functions over param dicts; every function
is vmap/scan/pjit friendly and takes an explicit dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# ------------------------------------------------- activation sharding

# Set by launch/steps.py before tracing distributed steps; empty (the
# default) → constraints are no-ops, so single-device tests/examples are
# unaffected.  XLA's sharding propagation alone tends to carry the
# embedding table's FEATURE sharding onto activations and replicate the
# batch — these explicit constraints pin batch→data axes (MaxText-style).
_BATCH_AXES: tuple[str, ...] = ()
_DP_SIZE: int = 1
_MODEL_SIZE: int = 1
_SEQ_PARALLEL: bool = False
_MESH = None
_FLASH_DECODE: bool = False


def set_mesh_axes(batch_axes: tuple[str, ...], dp_size: int,
                  model_size: int, *, seq_parallel: bool = False,
                  mesh=None, flash_decode: bool = False) -> None:
    global _BATCH_AXES, _DP_SIZE, _MODEL_SIZE, _SEQ_PARALLEL, _MESH, \
        _FLASH_DECODE
    _BATCH_AXES = tuple(batch_axes)
    _DP_SIZE = dp_size
    _MODEL_SIZE = model_size
    _SEQ_PARALLEL = seq_parallel
    _MESH = mesh
    _FLASH_DECODE = flash_decode


def clear_mesh_axes() -> None:
    set_mesh_axes((), 1, 1)


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin dim0 (batch) to the data axes (if divisible).  In seq-parallel
    mode, additionally shard dim1 (sequence) over `model`: the layer-carry
    residuals saved for backward shrink by the TP degree, at the cost of an
    all-gather feeding each attention block (Korthikanti et al.)."""
    from jax.sharding import PartitionSpec as P
    if not _BATCH_AXES or x.shape[0] % _DP_SIZE != 0:
        return x
    rest = [None] * (x.ndim - 1)
    if _SEQ_PARALLEL and x.ndim >= 3 and x.shape[1] % _MODEL_SIZE == 0:
        rest[0] = "model"
    spec = P(_BATCH_AXES, *rest)
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_batch_vocab(x: jax.Array) -> jax.Array:
    """Pin (B, ..., V) logits: batch→data, vocab→model (if divisible)."""
    from jax.sharding import PartitionSpec as P
    if not _BATCH_AXES:
        return x
    first = _BATCH_AXES if x.shape[0] % _DP_SIZE == 0 else None
    last = "model" if x.shape[-1] % _MODEL_SIZE == 0 else None
    if first is None and last is None:
        return x
    spec = P(first, *([None] * (x.ndim - 2)), last)
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------- normalize


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------- RoPE


def rope_angles(positions: jax.Array, head_dim: int, theta: float
                ) -> tuple[jax.Array, jax.Array]:
    """positions (..., S) int32 → (sin, cos) of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x (B, S, H, hd); sin/cos (B, S, hd//2) or (S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:
        sin = sin[None]
        cos = cos[None]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


def mrope_angles(positions: jax.Array, head_dim: int, theta: float,
                 sections=(2, 1, 1)) -> tuple[jax.Array, jax.Array]:
    """M-RoPE (Qwen2-VL): positions (B, 3, S) for (t, h, w); the rotary
    spectrum is split into `sections` (t:h:w proportional chunks) so each
    frequency band rotates by its own coordinate.  For pure text the three
    coordinates are identical and this reduces to standard RoPE."""
    half = head_dim // 2
    total = sum(sections)
    bounds = []
    start = 0
    for s in sections:
        size = half * s // total
        bounds.append((start, start + size))
        start = start + size
    bounds[-1] = (bounds[-1][0], half)    # absorb rounding into last chunk
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    sins, coss = [], []
    for i, (lo, hi) in enumerate(bounds):
        ang = positions[:, i, :, None].astype(jnp.float32) * freqs[lo:hi]
        sins.append(jnp.sin(ang))
        coss.append(jnp.cos(ang))
    return jnp.concatenate(sins, -1), jnp.concatenate(coss, -1)   # (B, S, half)


# -------------------------------------------------------------- attention


def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, KV, hd) → (B, S, KV·n_rep, hd) for GQA."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)
                            ).reshape(b, s, kv * n_rep, hd)


def _mask_logits(logits: jax.Array, *, causal: bool, window, offset,
                 kv_len_valid=None) -> jax.Array:
    """Apply causal / sliding-window masking to (B, H, Sq, Sk) logits using
    fused iota comparisons — the (Sq, Sk) mask is never materialized in HBM.

    window: None/0 → full; int or traced scalar → sliding (kpos > qpos−W).
    offset: absolute position of query row 0 (decode: cache length).
    kv_len_valid: optional traced scalar — keys ≥ this are padding.
    """
    sq, sk = logits.shape[-2], logits.shape[-1]
    qpos = lax.broadcasted_iota(jnp.int32, (sq, sk), 0) + offset
    kpos = lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        m &= jnp.where(w > 0, kpos > qpos - w, True)
    if kv_len_valid is not None:
        m &= kpos < kv_len_valid
    return jnp.where(m[None, None], logits, -1e30)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window=None, offset: int | jax.Array = 0,
              kv_len_valid=None, q_block: int = 0) -> jax.Array:
    """Softmax attention. q (B,Sq,H,hd), k/v (B,Sk,H,hd) (H already GQA-
    repeated).  q_block>0 streams over query blocks (flash-style memory:
    peak activation (B, H, q_block, Sk) instead of (B, H, Sq, Sk))."""
    scale = q.shape[-1] ** -0.5

    def blk(qb, off):
        logits = jnp.einsum("bqhd,bkhd->bhqk", qb, k,
                            preferred_element_type=jnp.float32) * scale
        logits = _mask_logits(logits, causal=causal, window=window,
                              offset=off, kv_len_valid=kv_len_valid)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    sq = q.shape[1]
    if not q_block or sq <= q_block:
        return blk(q, offset)
    assert sq % q_block == 0
    nb = sq // q_block
    qr = q.reshape(q.shape[0], nb, q_block, *q.shape[2:])

    def body(i, acc):
        ob = blk(qr[:, i], offset + i * q_block)
        return lax.dynamic_update_slice_in_dim(acc, ob[:, None], i, axis=1)

    out = jnp.zeros((q.shape[0], nb, q_block, *q.shape[2:]), v.dtype)
    out = lax.fori_loop(0, nb, body, out)
    return out.reshape(q.shape[0], sq, *q.shape[2:])


def gqa_attention(x: jax.Array, p: dict, cfg, *, sin, cos,
                  causal: bool = True, window=None,
                  offset: int | jax.Array = 0, kv_len_valid=None,
                  kv_override: tuple[jax.Array, jax.Array] | None = None,
                  q_block: int = 0) -> jax.Array:
    """Full GQA attention over x (B, S, D) with params p:
    wq (D, H·hd) [+bq], wk/wv (D, KV·hd) [+bk/bv], wo (H·hd, D).
    kv_override: precomputed (k, v) — cross-attention / KV-cache decode."""
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    if "bq" in p:
        q = q + p["bq"].reshape(1, 1, h, hd).astype(q.dtype)
    if kv_override is None:
        k = (x @ p["wk"]).reshape(b, s, kv, hd)
        v = (x @ p["wv"]).reshape(b, s, kv, hd)
        if "bk" in p:
            k = k + p["bk"].reshape(1, 1, kv, hd).astype(k.dtype)
            v = v + p["bv"].reshape(1, 1, kv, hd).astype(v.dtype)
        if sin is not None:
            k = apply_rope(k, sin, cos)
    else:
        k, v = kv_override
    if sin is not None:
        q_sin, q_cos = sin, cos
        if kv_override is not None and sin.shape[-2] != s:
            # decode: rope for the query position only (last offset slots)
            q_sin = lax.dynamic_slice_in_dim(sin, sin.shape[-2] - s, s, -2)
            q_cos = lax.dynamic_slice_in_dim(cos, cos.shape[-2] - s, s, -2)
        q = apply_rope(q, q_sin, q_cos)
    # Flash-decoding: single-token decode against a SEQUENCE-sharded cache
    # (KV heads don't divide the TP axis) — partial-softmax shard_map
    # instead of letting XLA all-gather the cache (see flash_decode.py).
    if (_FLASH_DECODE and _MESH is not None and kv_override is not None
            and s == 1 and k.shape[2] % _MODEL_SIZE != 0):
        from repro.models.flash_decode import flash_decode
        out = flash_decode(q, k, v, offset, mesh=_MESH,
                           dp_axes=_BATCH_AXES, n_rep=h // k.shape[2],
                           window=window)
        return out.reshape(b, s, h * hd) @ p["wo"]
    k = repeat_kv(k, h // k.shape[2])
    v = repeat_kv(v, h // v.shape[2])
    out = attention(q, k, v, causal=causal, window=window, offset=offset,
                    kv_len_valid=kv_len_valid, q_block=q_block)
    return out.reshape(b, s, h * hd) @ p["wo"]


def project_kv(x: jax.Array, p: dict, cfg, sin=None, cos=None
               ) -> tuple[jax.Array, jax.Array]:
    """K/V projection only (cache fill / cross-attention encoder side)."""
    b, s, _ = x.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    k = (x @ p["wk"]).reshape(b, s, kv, hd)
    v = (x @ p["wv"]).reshape(b, s, kv, hd)
    if "bk" in p:
        k = k + p["bk"].reshape(1, 1, kv, hd).astype(k.dtype)
        v = v + p["bv"].reshape(1, 1, kv, hd).astype(v.dtype)
    if sin is not None:
        k = apply_rope(k, sin, cos)
    return k, v


# ------------------------------------------------------------------- FFN


def swiglu(x: jax.Array, p: dict) -> jax.Array:
    """SwiGLU: (silu(x·wg) ⊙ (x·wu)) · wd with wg/wu (D,F), wd (F,D)."""
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


# ------------------------------------------------------------------ init


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def attn_params(key, cfg, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def swiglu_params(key, d: int, f: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {"wg": dense_init(ks[0], (d, f), dtype),
            "wu": dense_init(ks[1], (d, f), dtype),
            "wd": dense_init(ks[2], (f, d), dtype)}
