"""Mixture-of-experts FFN (Mixtral / Phi-3.5-MoE style): top-k routing with
GShard-style capacity dispatch via one-hot matmuls (MXU-friendly, fully
static shapes — the TPU-native formulation; no sorting / scatter).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def moe_params(key, cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), dtype),
        "wg": dense_init(ks[1], (e, d, f), dtype),
        "wu": dense_init(ks[2], (e, d, f), dtype),
        "wd": dense_init(ks[3], (e, f, d), dtype),
    }


def moe_ffn(x: jax.Array, p: dict, cfg, *, group_size: int = 512
            ) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) → (out, aux_loss).

    GROUPED dispatch (GShard-style): tokens are split into groups of
    `group_size`; each group routes to per-group expert buffers of capacity
    C_g = cf·Tg·k/E (overflow drops).  The dispatch one-hot is then
    (G, Tg, E, C_g) — linear in T, not O(T²/E) like a global-capacity
    dispatch — and the group axis shards over the data mesh axes while the
    expert axis of the weights shards over `model` (expert parallelism;
    XLA inserts the token all-to-all).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    t = b * s
    tg = min(group_size, t)
    assert t % tg == 0, (t, tg)
    g = t // tg
    cap = max(int(cfg.capacity_factor * tg * k / e), 1)
    xt = x.reshape(g, tg, d)

    logits = xt @ p["router"]                        # (G, Tg, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (G, Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # buffer position of each (token, choice) within its group's expert
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.int32)   # (G, Tg, k, E)
    flat = onehot.reshape(g, tg * k, e)
    pos = jnp.cumsum(flat, axis=1) * flat - 1
    pos = pos.reshape(g, tg, k, e)
    within_cap = (pos >= 0) & (pos < cap)

    slot = jnp.sum(jnp.where(within_cap, pos, 0) * onehot, axis=-1)
    keep = jnp.any(within_cap & (onehot > 0), axis=-1)        # (G, Tg, k)
    disp = (jax.nn.one_hot(slot, cap, dtype=x.dtype)
            * keep[..., None].astype(x.dtype))                # (G, Tg, k, C)
    oh = onehot.astype(x.dtype)
    dispatch = jnp.einsum("gtke,gtkc->gtec", oh, disp)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", oh, disp,
                         gate_vals.astype(x.dtype))

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xt)           # (G, E, C, D)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"])) \
        * jnp.einsum("gecd,edf->gecf", xe, p["wu"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])             # (G, E, C, D)
    out = jnp.einsum("gtec,gecd->gtd", combine, ye).reshape(b, s, d)

    # load-balancing aux loss (Switch): E · Σ_e f_e · P_e
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    fe = jnp.mean(jnp.sum(onehot, axis=2).astype(jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * fe)
    return out, aux
