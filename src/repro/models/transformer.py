"""Generic decoder-only transformer LM covering the dense/MoE/VLM assigned
architectures (qwen2/2.5/1.5, qwen2-vl via M-RoPE + embedding inputs,
gemma3 local:global interleave, mixtral / phi3.5-moe via MoE FFN).

Structure: stacked layer params (leading axis L) consumed by lax.scan, so
the HLO stays compact for the 512-device dry-run; activation checkpointing
wraps the block body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.scan_util import scan_layers
from repro.models.moe import moe_ffn, moe_params


# ------------------------------------------------------------------ flags


def layer_is_local(cfg, i: int) -> bool:
    """gemma3 pattern: ratio local then 1 global, repeating."""
    r = cfg.local_global_ratio
    if not r or not cfg.sliding_window:
        return bool(cfg.sliding_window)
    return (i % (r + 1)) != r


def layer_windows(cfg) -> jax.Array:
    """(L,) int32 — sliding window per layer (0 = full attention)."""
    return jnp.asarray([cfg.sliding_window if layer_is_local(cfg, i) else 0
                        for i in range(cfg.n_layers)], jnp.int32)


# ------------------------------------------------------------------- init


def init(key: jax.Array, cfg, dtype=jnp.float32) -> dict:
    k_emb, k_blocks, k_head = jax.random.split(key, 3)

    def block_init(bkey):
        ka, kf, kn = jax.random.split(bkey, 3)
        p = {"ln1": jnp.ones((cfg.d_model,), dtype),
             "ln2": jnp.ones((cfg.d_model,), dtype),
             "attn": L.attn_params(ka, cfg, dtype)}
        if cfg.is_moe:
            p["moe"] = moe_params(kf, cfg, dtype)
        else:
            p["ffn"] = L.swiglu_params(kf, cfg.d_model, cfg.d_ff, dtype)
        return p

    blocks = jax.vmap(block_init)(jax.random.split(k_blocks, cfg.n_layers))
    params = {
        "embed": L.dense_init(k_emb, (cfg.vocab, cfg.d_model), dtype, 0.02),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab),
                                         dtype)
    return params


# ---------------------------------------------------------------- forward


def _block(x, bp, cfg, *, sin, cos, window, causal=True, offset=0,
           q_block=0):
    h = L.gqa_attention(L.rms_norm(x, bp["ln1"], cfg.norm_eps), bp["attn"],
                        cfg, sin=sin, cos=cos, causal=causal, window=window,
                        offset=offset, q_block=q_block)
    x = x + h
    z = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        f, aux = moe_ffn(z, bp["moe"], cfg)
    else:
        f, aux = L.swiglu(z, bp["ffn"]), jnp.asarray(0.0, jnp.float32)
    return x + f, aux


def _angles(cfg, positions, b, s):
    if cfg.rope_style == "none":
        return None, None
    if cfg.rope_style == "mrope":
        if positions is None:
            pos1 = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
            positions = jnp.stack([pos1] * 3, axis=1)        # (B, 3, S)
        return L.mrope_angles(positions, cfg.hd, cfg.rope_theta)
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
        return L.rope_angles(positions, cfg.hd, cfg.rope_theta)
    return L.rope_angles(positions, cfg.hd, cfg.rope_theta)


@functools.partial(jax.jit, static_argnames=("cfg", "q_block", "remat",
                                              "last_only"))
def forward(params: dict, tokens: jax.Array, cfg, *, embeds=None,
            positions=None, q_block: int = 0, remat: bool = True,
            last_only: bool = False) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) int32 (or embeds (B, S, D) for stubbed frontends)
    → (logits (B, S, V), aux_loss).  last_only: compute the LM head only on
    the final position (prefill serving — avoids the (B,S,V) tensor)."""
    x = L.constrain_batch(params["embed"][tokens] if embeds is None
                          else embeds)
    b, s = x.shape[0], x.shape[1]
    sin, cos = _angles(cfg, positions, b, s)
    windows = layer_windows(cfg)

    def body(carry, xs):
        bp, w = xs
        fn = functools.partial(_block, cfg=cfg, sin=sin, cos=cos,
                               q_block=q_block)
        if remat:
            # full remat: save only the per-layer carry (B,S,D); all block
            # internals (attention logits, FFN hiddens) recompute on the
            # backward pass — the standard memory/compute trade at scale.
            fn = jax.checkpoint(fn)
        x, aux = fn(carry[0], bp, window=w)
        return (L.constrain_batch(x), carry[1] + aux), None

    (x, aux), _ = scan_layers(body, (x, jnp.asarray(0.0, jnp.float32)),
                           (params["blocks"], windows))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    head = params.get("lm_head")
    logits = x @ head if head is not None else x @ params["embed"].T
    return L.constrain_batch_vocab(logits), aux


# ----------------------------------------------------------------- decode


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.float32) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((), jnp.int32)}


def prefill(params: dict, tokens: jax.Array, cfg, cache: dict,
            *, embeds=None, q_block: int = 0) -> tuple[jax.Array, dict]:
    """Run the full prompt, filling the KV cache; returns (last_logits, cache)."""
    x = L.constrain_batch(params["embed"][tokens] if embeds is None
                          else embeds)
    b, s = x.shape[0], x.shape[1]
    sin, cos = _angles(cfg, None, b, s)
    windows = layer_windows(cfg)

    def body(carry, xs):
        bp, w = xs
        x = carry
        xn = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        k, v = L.project_kv(xn, bp["attn"], cfg, sin, cos)
        h = L.gqa_attention(xn, bp["attn"], cfg, sin=sin, cos=cos,
                            causal=True, window=w, kv_override=(k, v),
                            q_block=q_block)
        x = x + h
        z = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        f = moe_ffn(z, bp["moe"], cfg)[0] if cfg.is_moe \
            else L.swiglu(z, bp["ffn"])
        return L.constrain_batch(x + f), (k, v)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"], windows))
    max_len = cache["k"].shape[2]
    pad = [(0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0)]
    cache = {"k": jnp.pad(ks, pad), "v": jnp.pad(vs, pad),
             "len": jnp.asarray(s, jnp.int32)}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = x[:, -1] @ head if head is not None \
        else x[:, -1] @ params["embed"].T
    return logits, cache


@functools.partial(jax.jit, static_argnames=("cfg",))
def decode_step(params: dict, tokens: jax.Array, cache: dict, cfg
                ) -> tuple[jax.Array, dict]:
    """One-token decode: tokens (B, 1) against the filled KV cache."""
    x = L.constrain_batch(params["embed"][tokens])    # (B, 1, D)
    b = x.shape[0]
    max_len = cache["k"].shape[2]
    pos = cache["len"]
    sin, cos = _angles(cfg, pos[None].astype(jnp.int32), b, 1) \
        if cfg.rope_style == "rope" else _angles(cfg, None, b, 1)
    if cfg.rope_style == "mrope":
        p1 = jnp.full((b, 1), pos, jnp.int32)
        sin, cos = L.mrope_angles(jnp.stack([p1] * 3, 1), cfg.hd,
                                  cfg.rope_theta)
    windows = layer_windows(cfg)

    def body(carry, xs):
        bp, w, ck, cv = xs
        x = carry
        xn = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        k_new, v_new = L.project_kv(xn, bp["attn"], cfg, sin, cos)
        ck = lax.dynamic_update_slice_in_dim(ck, k_new.astype(ck.dtype), pos,
                                             axis=1)
        cv = lax.dynamic_update_slice_in_dim(cv, v_new.astype(cv.dtype), pos,
                                             axis=1)
        h = L.gqa_attention(xn, bp["attn"], cfg, sin=sin, cos=cos,
                            causal=True, window=w, offset=pos,
                            kv_len_valid=pos + 1, kv_override=(ck, cv))
        x = x + h
        z = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        f = moe_ffn(z, bp["moe"], cfg)[0] if cfg.is_moe \
            else L.swiglu(z, bp["ffn"])
        return L.constrain_batch(x + f), (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"], windows,
                                     cache["k"], cache["v"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = x[:, -1] @ head if head is not None \
        else x[:, -1] @ params["embed"].T
    return logits, {"k": ks, "v": vs, "len": pos + 1}