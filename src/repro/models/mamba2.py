"""Mamba-2 (SSD — state-space duality) block, chunked-parallel training form
and single-step decode form.

Recurrence per head (state S ∈ R^{hd×N}):
    S_t = a_t · S_{t-1} + (Δ_t x_t) ⊗ B_t ,   a_t = exp(A·Δ_t) ∈ (0,1)
    y_t = S_t C_t + D · x_t
Training runs the chunkwise form: intra-chunk via a (Tc×Tc) masked-decay
matmul (MXU), inter-chunk via the carried state — O(T·Tc) instead of O(T²).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, rms_norm


def ssm_dims(cfg) -> tuple[int, int, int]:
    d_inner = 2 * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


def mamba_params(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_inner, h, n = ssm_dims(cfg)
    ks = jax.random.split(key, 5)
    conv_ch = d_inner + 2 * n
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_inner + 2 * n + h), dtype),
        "conv": dense_init(ks[1], (cfg.ssm_conv, conv_ch), dtype, 0.5),
        "A_log": jnp.zeros((h,), jnp.float32) + jnp.log(jnp.e),   # A = -e
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), dtype),
        "gate_norm": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[2], (d_inner, d), dtype),
    }


def _split_proj(z, cfg):
    d_inner, h, n = ssm_dims(cfg)
    zg = z[..., :d_inner]
    xs = z[..., d_inner:2 * d_inner]
    bmat = z[..., 2 * d_inner:2 * d_inner + n]
    cmat = z[..., 2 * d_inner + n:2 * d_inner + 2 * n]
    dt = z[..., 2 * d_inner + 2 * n:]
    return zg, xs, bmat, cmat, dt


def _causal_conv(u: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: u (B, T, C), w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i][None, None]
              for i in range(k))
    return jax.nn.silu(out)


def mamba_forward(x: jax.Array, p: dict, cfg, *, chunk: int = 256
                  ) -> jax.Array:
    """x (B, T, D) → (B, T, D).  T must divide by `chunk` (or be < chunk)."""
    b, t, d = x.shape
    d_inner, h, n = ssm_dims(cfg)
    hd = cfg.ssm_head_dim

    z = x @ p["in_proj"]
    zg, xs, bmat, cmat, dt = _split_proj(z, cfg)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv"])
    xs = conv_out[..., :d_inner]
    bmat = conv_out[..., d_inner:d_inner + n]
    cmat = conv_out[..., d_inner + n:]

    a_neg = -jnp.exp(p["A_log"])                                  # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,T,H)
    loga = dt * a_neg                                             # log a_t ≤ 0
    xh = xs.reshape(b, t, h, hd)
    xbar = xh * dt[..., None].astype(x.dtype)                     # Δ_t x_t

    if t <= chunk:
        chunk = t
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    # chunked views
    xbar_c = xbar.reshape(b, nc, chunk, h, hd)
    loga_c = loga.reshape(b, nc, chunk, h)
    b_c = bmat.reshape(b, nc, chunk, n)
    c_c = cmat.reshape(b, nc, chunk, n)

    def chunk_step(state, inputs):
        """state (B, H, hd, N); one chunk."""
        xb, la, bm, cm = inputs                      # (B,Tc,H,hd) (B,Tc,H) ..
        lcum = jnp.cumsum(la, axis=1)                # L_t
        # intra-chunk: M[t,s] = (C_t·B_s)·exp(L_t−L_s)·1[s≤t]
        g = jnp.einsum("btn,bsn->bts", cm, bm,
                       preferred_element_type=jnp.float32)
        decay = lcum[:, :, None, :] - lcum[:, None, :, :]         # (B,t,s,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        # mask BEFORE exp: for s>t the exponent is positive and overflows,
        # and where-after-exp leaks NaN into gradients (0·inf).
        decay = jnp.where(tri[None, :, :, None], decay, -1e30)
        m = jnp.exp(decay) * g[..., None]
        y_intra = jnp.einsum("btsh,bshp->bthp", m.astype(x.dtype), xb)
        # inter-chunk: y += exp(L_t)·C_t·S_prev
        y_inter = jnp.einsum("btn,bhpn->bthp", cm, state) \
            * jnp.exp(lcum)[..., None].astype(x.dtype)
        # state update: S = exp(L_Tc)·S_prev + Σ_s exp(L_Tc−L_s)·xb_s ⊗ B_s
        ltot = lcum[:, -1]                                        # (B,H)
        w = jnp.exp(ltot[:, None] - lcum)                         # (B,Tc,H)
        s_new = state * jnp.exp(ltot)[..., None, None].astype(x.dtype) \
            + jnp.einsum("bshp,bsn,bsh->bhpn", xb, bm, w.astype(x.dtype))
        return s_new, y_intra + y_inter

    s0 = jnp.zeros((b, h, hd, n), x.dtype)
    # scan over chunks (moveaxis: chunk axis leading); unrolled in dry-run
    # mode so cost_analysis sees every chunk's FLOPs
    from repro.models.scan_util import scan_layers
    xs_in = (jnp.moveaxis(xbar_c, 1, 0), jnp.moveaxis(loga_c, 1, 0),
             jnp.moveaxis(b_c, 1, 0), jnp.moveaxis(c_c, 1, 0))
    _, ys = scan_layers(chunk_step, s0, xs_in)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, hd)
    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, t, d_inner)
    y = rms_norm(y * jax.nn.silu(zg), p["gate_norm"])
    return y @ p["out_proj"]


def mamba_init_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    d_inner, h, n = ssm_dims(cfg)
    conv_ch = d_inner + 2 * n
    return {"ssm": jnp.zeros((batch, h, cfg.ssm_head_dim, n), dtype),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype)}


def mamba_step(x: jax.Array, state: dict, p: dict, cfg
               ) -> tuple[jax.Array, dict]:
    """Single-token decode: x (B, 1, D) + carried (ssm, conv) state."""
    b = x.shape[0]
    d_inner, h, n = ssm_dims(cfg)
    hd = cfg.ssm_head_dim

    z = x @ p["in_proj"]
    zg, xs, bmat, cmat, dt = _split_proj(z, cfg)
    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)    # (B,1,C)
    window = jnp.concatenate([state["conv"], conv_in], axis=1)
    conv_out = jax.nn.silu(jnp.sum(window * p["conv"][None], axis=1,
                                   keepdims=True))
    new_conv = window[:, 1:]
    xs = conv_out[..., :d_inner]
    bmat = conv_out[..., d_inner:d_inner + n][:, 0]         # (B,N)
    cmat = conv_out[..., d_inner + n:][:, 0]

    a_neg = -jnp.exp(p["A_log"])
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dtv * a_neg)                                 # (B,H)
    xh = xs.reshape(b, h, hd)
    xbar = xh * dtv[..., None].astype(x.dtype)
    s = state["ssm"] * a[..., None, None].astype(x.dtype) \
        + jnp.einsum("bhp,bn->bhpn", xbar, bmat)
    y = jnp.einsum("bhpn,bn->bhp", s, cmat) \
        + xh * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(b, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(zg), p["gate_norm"])
    return y @ p["out_proj"], {"ssm": s, "conv": new_conv}
