"""xLSTM blocks: mLSTM (matrix memory, chunk-free stabilized scan) and
sLSTM (scalar memory, sequential by construction).

mLSTM per head (state C ∈ R^{hd×hd}, n ∈ R^{hd}, stabilizer m):
    m_t = max(log f_t + m_{t-1}, log i_t)
    C_t = exp(log f_t + m_{t-1} − m_t)·C_{t-1} + exp(log i_t − m_t)·v_t k_tᵀ
    n_t likewise with k_t;  h_t = o_t ⊙ (C_t q_t) / max(|n_tᵀ q_t|, 1)

The time recurrence runs as lax.scan (the xLSTM paper's "recurrent mode");
FLOP-equivalent to the chunkwise-parallel form.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, rms_norm


def xlstm_dims(cfg) -> tuple[int, int, int]:
    h = cfg.n_heads
    d_inner = 2 * cfg.d_model
    hd = d_inner // h
    return d_inner, h, hd


def mlstm_params(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_inner, h, hd = xlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "up": dense_init(ks[0], (d, 2 * d_inner), dtype),
        "wq": dense_init(ks[1], (d_inner, d_inner), dtype),
        "wk": dense_init(ks[2], (d_inner, d_inner), dtype),
        "wv": dense_init(ks[3], (d_inner, d_inner), dtype),
        "w_if": dense_init(ks[4], (d_inner, 2 * h), dtype, 0.01),
        "out_norm": jnp.ones((d_inner,), dtype),
        "down": dense_init(ks[5], (d_inner, d), dtype),
    }


def mlstm_forward(x: jax.Array, p: dict, cfg, *, chunk: int = 256
                  ) -> jax.Array:
    """x (B, T, D) → (B, T, D), CHUNKWISE-PARALLEL form.

    Derivation (matches the stabilized recurrence in mlstm_step exactly):
    with L_t = Σ_{τ≤t} log f_τ (within chunk), u_s = log i_s − L_s,
    M_t = max(m_carry, cummax_{s≤t} u_s) and m_t = L_t + M_t:

        num_t = Σ_{s≤t} e^{u_s − M_t} (q_t·k_s) v_s + e^{m_c − M_t}(Ĉ q_t)
        n̂_t·q = same weights with k_s;  y_t = num_t / max(|n̂_t·q_t|, 1)

    so the intra-chunk work is a (Tc×Tc) masked matmul per head (MXU) and
    the carry update reuses the same weights at t = Tc.
    """
    from repro.models.scan_util import scan_layers

    b, t, d = x.shape
    d_inner, h, hd = xlstm_dims(cfg)
    up = x @ p["up"]
    u, gate = up[..., :d_inner], up[..., d_inner:]
    q = (u @ p["wq"]).reshape(b, t, h, hd) * hd ** -0.5
    k = (u @ p["wk"]).reshape(b, t, h, hd) * hd ** -0.5
    v = (u @ p["wv"]).reshape(b, t, h, hd)
    gif = (u @ p["w_if"]).astype(jnp.float32)
    log_i = gif[..., :h]                                  # (B,T,H)
    log_f = jax.nn.log_sigmoid(gif[..., h:])              # log f ∈ (−∞, 0)

    if t <= chunk:
        chunk = t
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    def reshape_c(a):
        return jnp.moveaxis(a.reshape(b, nc, chunk, *a.shape[2:]), 1, 0)

    def chunk_step(carry, inp):
        c_hat, n_hat, m_c = carry          # (B,H,hd,hd), (B,H,hd), (B,H)
        qc, kc, vc, li, lf = inp           # (B,Tc,H,…)
        lcum = jnp.cumsum(lf, axis=1)                      # L_t (B,Tc,H)
        us = li - lcum                                     # u_s
        m_run = jnp.maximum(jax.lax.cummax(us, axis=1), m_c[:, None])
        w_intra = jnp.exp(us[:, None, :, :] - m_run[:, :, None, :])
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        w_intra = jnp.where(tri[None, :, :, None], w_intra, 0.0)  # (B,t,s,H)
        attn = jnp.einsum("bthp,bshp->btsh", qc, kc,
                          preferred_element_type=jnp.float32)
        aw = (attn * w_intra).astype(x.dtype)
        num = jnp.einsum("btsh,bshp->bthp", aw, vc)
        den_i = jnp.einsum("btsh,bshp->bthp", aw, kc)
        w_carry = jnp.exp(m_c[:, None] - m_run)            # (B,Tc,H)
        num = num + w_carry[..., None].astype(x.dtype) \
            * jnp.einsum("bhpq,bthq->bthp", c_hat, qc)
        den = jnp.einsum("bthp,bthp->bth", den_i, qc) \
            + w_carry * jnp.einsum("bhq,bthq->bth", n_hat, qc)
        y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None].astype(x.dtype)
        # carry update at chunk end: stabilized quantities use M_Tc, but the
        # CARRIED stabilizer is the absolute m_Tc = L_Tc + M_Tc (the next
        # chunk restarts its L at 0, so m_c must absorb this chunk's decay).
        m_big = m_run[:, -1]                               # M_Tc (B,H)
        w_end = jnp.exp(us - m_big[:, None])               # (B,Tc,H)
        c_new = jnp.exp(m_c - m_big)[..., None, None].astype(x.dtype) \
            * c_hat + jnp.einsum("bthp,bthq,bth->bhpq", vc, kc,
                                 w_end.astype(x.dtype))
        n_new = jnp.exp(m_c - m_big)[..., None].astype(x.dtype) * n_hat \
            + jnp.einsum("bthq,bth->bhq", kc, w_end.astype(x.dtype))
        m_carry_out = lcum[:, -1] + m_big                  # m_Tc
        return (c_new, n_new, m_carry_out), y

    c0 = jnp.zeros((b, h, hd, hd), x.dtype)
    n0 = jnp.zeros((b, h, hd), x.dtype)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    xs = tuple(reshape_c(a) for a in (q, k, v, log_i, log_f))
    _, ys = scan_layers(chunk_step, (c0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, d_inner)
    y = rms_norm(y, p["out_norm"]) * jax.nn.silu(gate)
    return y @ p["down"]


def slstm_params(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_inner, h, hd = xlstm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "up": dense_init(ks[0], (d, d_inner), dtype),
        "w_gates": dense_init(ks[1], (d_inner, 4 * d_inner), dtype),
        "r_gates": dense_init(ks[2], (h, hd, 4 * hd), dtype, 0.1),
        "out_norm": jnp.ones((d_inner,), dtype),
        "down": dense_init(ks[3], (d_inner, d), dtype),
    }


def slstm_forward(x: jax.Array, p: dict, cfg) -> jax.Array:
    """sLSTM with per-head recurrent mixing (block-diagonal R)."""
    b, t, d = x.shape
    d_inner, h, hd = xlstm_dims(cfg)
    u = (x @ p["up"]).reshape(b, t, h, hd)
    wg = (u.reshape(b, t, d_inner) @ p["w_gates"]).reshape(b, t, h, 4 * hd)

    def step(carry, inp):
        c_s, n_s, h_s, m_s = carry                       # (B,H,hd) each
        wgt = inp                                        # (B,H,4·hd)
        rec = jnp.einsum("bhp,hpq->bhq", h_s, p["r_gates"])
        g = (wgt + rec).astype(jnp.float32)
        zi, ii, fi, oi = jnp.split(g, 4, axis=-1)
        zt = jnp.tanh(zi)
        ot = jax.nn.sigmoid(oi)
        log_f = jax.nn.log_sigmoid(fi)
        m_new = jnp.maximum(log_f + m_s, ii)
        c_new = jnp.exp(log_f + m_s - m_new) * c_s + jnp.exp(ii - m_new) * zt
        n_new = jnp.exp(log_f + m_s - m_new) * n_s + jnp.exp(ii - m_new)
        h_new = (ot * c_new / jnp.maximum(n_new, 1e-6)).astype(x.dtype)
        return (c_new.astype(jnp.float32), n_new.astype(jnp.float32),
                h_new, m_new), h_new

    c0 = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.full((b, h, hd), -1e30, jnp.float32)
    h0 = jnp.zeros((b, h, hd), x.dtype)
    _, ys = lax.scan(step, (c0, c0, h0, m0), jnp.moveaxis(wg, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, d_inner)
    y = rms_norm(y, p["out_norm"])
    return y @ p["down"]


# --------------------------------------------------------------- decode


def mlstm_init_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    d_inner, h, hd = xlstm_dims(cfg)
    return {"c": jnp.zeros((batch, h, hd, hd), dtype),
            "n": jnp.zeros((batch, h, hd), dtype),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


def mlstm_step(x: jax.Array, state: dict, p: dict, cfg
               ) -> tuple[jax.Array, dict]:
    """x (B, 1, D) single-token decode."""
    b = x.shape[0]
    d_inner, h, hd = xlstm_dims(cfg)
    up = x[:, 0] @ p["up"]
    u, gate = up[..., :d_inner], up[..., d_inner:]
    q = (u @ p["wq"]).reshape(b, h, hd) * hd ** -0.5
    k = (u @ p["wk"]).reshape(b, h, hd) * hd ** -0.5
    v = (u @ p["wv"]).reshape(b, h, hd)
    gif = (u @ p["w_if"]).astype(jnp.float32)
    li, lf = gif[..., :h], jax.nn.log_sigmoid(gif[..., h:])
    m_new = jnp.maximum(lf + state["m"], li)
    fw = jnp.exp(lf + state["m"] - m_new)[..., None, None].astype(x.dtype)
    iw = jnp.exp(li - m_new)[..., None, None].astype(x.dtype)
    c_new = fw * state["c"] + iw * jnp.einsum("bhp,bhq->bhpq", v, k)
    n_new = fw[..., 0] * state["n"] + iw[..., 0] * k
    num = jnp.einsum("bhpq,bhq->bhp", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhq,bhq->bh", n_new, q)),
                      1.0)[..., None]
    y = (num / den).reshape(b, 1, d_inner)
    y = rms_norm(y, p["out_norm"]) * jax.nn.silu(gate[:, None])
    return y @ p["down"], {"c": c_new, "n": n_new, "m": m_new}


def slstm_init_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    d_inner, h, hd = xlstm_dims(cfg)
    return {"c": jnp.zeros((batch, h, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "h": jnp.zeros((batch, h, hd), dtype),
            "m": jnp.full((batch, h, hd), -1e30, jnp.float32)}


def slstm_step(x: jax.Array, state: dict, p: dict, cfg
               ) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    d_inner, h, hd = xlstm_dims(cfg)
    u = (x[:, 0] @ p["up"]).reshape(b, h, hd)
    wgt = (u.reshape(b, d_inner) @ p["w_gates"]).reshape(b, h, 4 * hd)
    rec = jnp.einsum("bhp,hpq->bhq", state["h"], p["r_gates"])
    g = (wgt + rec).astype(jnp.float32)
    zi, ii, fi, oi = jnp.split(g, 4, axis=-1)
    zt = jnp.tanh(zi)
    ot = jax.nn.sigmoid(oi)
    log_f = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(log_f + state["m"], ii)
    c_new = jnp.exp(log_f + state["m"] - m_new) * state["c"] \
        + jnp.exp(ii - m_new) * zt
    n_new = jnp.exp(log_f + state["m"] - m_new) * state["n"] \
        + jnp.exp(ii - m_new)
    h_new = (ot * c_new / jnp.maximum(n_new, 1e-6)).astype(x.dtype)
    y = rms_norm(h_new.reshape(b, 1, d_inner), p["out_norm"])
    return y @ p["down"], {"c": c_new, "n": n_new, "h": h_new, "m": m_new}
