"""Layer-stack iteration: lax.scan by default (compact HLO), unrolled
Python loop when REPRO_UNROLL=1.

Why: XLA's cost_analysis() does not multiply a while-loop body by its trip
count, so the dry-run's roofline FLOPs/bytes/collectives would undercount
L-layer models by ~L×.  dryrun.py sets REPRO_UNROLL=1 to lower the honest
(unrolled) module; training/serving keep the scan.
"""

from __future__ import annotations

import os

import jax
from jax import lax


def unrolling() -> bool:
    return os.environ.get("REPRO_UNROLL", "0") == "1"


def scan_layers(body, carry, xs, *, length: int | None = None):
    """Drop-in for lax.scan(body, carry, xs) over stacked layer params.

    Unrolled mode indexes each layer's slice (constant indices — XLA emits
    plain slices, no gathers) and stacks the per-layer outputs.
    """
    if not unrolling():
        return lax.scan(body, carry, xs, length=length)
    if length is None:
        length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda a: a[i], xs) if xs is not None else None
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jax.numpy.stack(a), *ys)
    else:
        ys = None
    return carry, ys
