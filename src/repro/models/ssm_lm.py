"""SSM/hybrid language models: xlstm-1.3b (mLSTM + periodic sLSTM) and
zamba2 (Mamba-2 backbone + shared attention block applied periodically).

Heterogeneous layer stacks are organized as GROUP SCANS so the HLO stays
compact: a group = (period−1 or period) homogeneous inner layers (stacked,
inner lax.scan) + the special layer; the outer lax.scan runs over groups.
zamba2's attention block is SHARED (one set of weights applied at every
attention position — the paper's parameter-sharing trick), so it enters
the group body as a closure, not a scanned input.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.scan_util import scan_layers
from repro.models import mamba2, xlstm
from repro.models.layers import rms_norm


# ------------------------------------------------------------------ xLSTM


def xlstm_groups(cfg) -> tuple[int, int]:
    """(n_groups, mlstm_per_group): layers = g·(m+1) with one sLSTM/group."""
    period = cfg.slstm_every or cfg.n_layers
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    return cfg.n_layers // period, period - 1


def xlstm_init(key, cfg, dtype=jnp.float32) -> dict:
    g, m = xlstm_groups(cfg)
    k_emb, k_m, k_s, k_h = jax.random.split(key, 4)

    def one_m(k):
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "mlstm": xlstm.mlstm_params(k, cfg, dtype)}

    def one_s(k):
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "slstm": xlstm.slstm_params(k, cfg, dtype)}

    mkeys = jax.random.split(k_m, g * m).reshape(g, m, 2)
    return {
        "embed": L.dense_init(k_emb, (cfg.vocab, cfg.d_model), dtype, 0.02),
        "mlstm_blocks": jax.vmap(jax.vmap(one_m))(mkeys),
        "slstm_blocks": jax.vmap(one_s)(jax.random.split(k_s, g)),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": L.dense_init(k_h, (cfg.d_model, cfg.vocab), dtype),
    }


@functools.partial(jax.jit, static_argnames=("cfg", "remat", "last_only"))
def xlstm_forward(params, tokens, cfg, *, embeds=None, remat=True,
                  last_only=False):
    x = L.constrain_batch(params["embed"][tokens] if embeds is None
                          else embeds)

    def m_layer(x, bp):
        fn = lambda xx, pp: xx + xlstm.mlstm_forward(
            rms_norm(xx, pp["ln"], cfg.norm_eps), pp["mlstm"], cfg)
        if remat:
            fn = jax.checkpoint(fn)
        return fn(x, bp), None

    def group(x, gxs):
        m_bp, s_bp = gxs
        x, _ = scan_layers(m_layer, x, m_bp)
        x = x + xlstm.slstm_forward(rms_norm(x, s_bp["ln"], cfg.norm_eps),
                                    s_bp["slstm"], cfg)
        return L.constrain_batch(x), None

    x, _ = scan_layers(group, x, (params["mlstm_blocks"],
                               params["slstm_blocks"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    return L.constrain_batch_vocab(x @ params["lm_head"]), \
        jnp.asarray(0.0, jnp.float32)


def xlstm_init_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    g, m = xlstm_groups(cfg)

    def stack(tree, reps):
        return jax.tree.map(lambda a: jnp.broadcast_to(
            a, reps + a.shape), tree)

    return {"m": stack(xlstm.mlstm_init_state(cfg, batch, dtype), (g, m)),
            "s": stack(xlstm.slstm_init_state(cfg, batch, dtype), (g,)),
            "len": jnp.zeros((), jnp.int32)}


@functools.partial(jax.jit, static_argnames=("cfg",))
def xlstm_decode_step(params, tokens, cache, cfg):
    x = params["embed"][tokens]                     # (B, 1, D)

    def m_layer(x, xs):
        bp, st = xs
        y, st_new = xlstm.mlstm_step(
            rms_norm(x, bp["ln"], cfg.norm_eps), st, bp["mlstm"], cfg)
        return x + y, st_new

    def group(x, gxs):
        m_bp, s_bp, m_st, s_st = gxs
        x, m_st_new = scan_layers(m_layer, x, (m_bp, m_st))
        y, s_st_new = xlstm.slstm_step(
            rms_norm(x, s_bp["ln"], cfg.norm_eps), s_st, s_bp["slstm"], cfg)
        return x + y, (m_st_new, s_st_new)

    x, (m_new, s_new) = scan_layers(group, x, (params["mlstm_blocks"],
                                            params["slstm_blocks"],
                                            cache["m"], cache["s"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ params["lm_head"]
    return logits, {"m": m_new, "s": s_new, "len": cache["len"] + 1}


# ------------------------------------------------------------------ zamba2


def zamba_groups(cfg) -> tuple[int, int, int]:
    """(n_groups, mamba_per_group, tail_layers)."""
    per = cfg.attn_every
    g = cfg.n_layers // per
    return g, per, cfg.n_layers - g * per


def zamba_init(key, cfg, dtype=jnp.float32) -> dict:
    g, per, tail = zamba_groups(cfg)
    ks = jax.random.split(key, 6)

    def one_m(k):
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "mamba": mamba2.mamba_params(k, cfg, dtype)}

    shared_attn = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": L.attn_params(ks[2], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "ffn": L.swiglu_params(ks[3], cfg.d_model, cfg.d_ff, dtype),
    }
    params = {
        "embed": L.dense_init(ks[0], (cfg.vocab, cfg.d_model), dtype, 0.02),
        "groups": jax.vmap(jax.vmap(one_m))(
            jax.random.split(ks[1], g * per).reshape(g, per, 2)),
        "shared_attn": shared_attn,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": L.dense_init(ks[4], (cfg.d_model, cfg.vocab), dtype),
    }
    if tail:
        params["tail"] = jax.vmap(one_m)(jax.random.split(ks[5], tail))
    return params


def _zamba_attn(x, sp, cfg, *, sin, cos, q_block=0):
    h = L.gqa_attention(rms_norm(x, sp["ln1"], cfg.norm_eps), sp["attn"],
                        cfg, sin=sin, cos=cos, causal=True, q_block=q_block)
    x = x + h
    return x + L.swiglu(rms_norm(x, sp["ln2"], cfg.norm_eps), sp["ffn"])


@functools.partial(jax.jit, static_argnames=("cfg", "remat", "last_only"))
def zamba_forward(params, tokens, cfg, *, embeds=None, remat=True,
                  last_only=False):
    cfg_attn = cfg
    x = L.constrain_batch(params["embed"][tokens] if embeds is None
                          else embeds)
    b, s = x.shape[0], x.shape[1]
    sin, cos = L.rope_angles(jnp.arange(s, dtype=jnp.int32), cfg.hd,
                             cfg.rope_theta)
    sp = params["shared_attn"]

    def m_layer(x, bp):
        fn = lambda xx, pp: xx + mamba2.mamba_forward(
            rms_norm(xx, pp["ln"], cfg.norm_eps), pp["mamba"], cfg)
        if remat:
            fn = jax.checkpoint(fn)
        return fn(x, bp), None

    def group(x, g_bp):
        x, _ = scan_layers(m_layer, x, g_bp)
        return L.constrain_batch(
            _zamba_attn(x, sp, cfg_attn, sin=sin, cos=cos)), None

    x, _ = scan_layers(group, x, params["groups"])
    if "tail" in params:
        x, _ = scan_layers(m_layer, x, params["tail"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    return L.constrain_batch_vocab(x @ params["lm_head"]), \
        jnp.asarray(0.0, jnp.float32)


def zamba_init_cache(cfg, batch: int, max_len: int, dtype=jnp.float32
                     ) -> dict:
    g, per, tail = zamba_groups(cfg)

    def stack(tree, reps):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, reps + a.shape),
                            tree)

    cache = {
        "ssm": stack(mamba2.mamba_init_state(cfg, batch, dtype), (g, per)),
        "attn_k": jnp.zeros((g, batch, max_len, cfg.n_kv_heads, cfg.hd),
                            dtype),
        "attn_v": jnp.zeros((g, batch, max_len, cfg.n_kv_heads, cfg.hd),
                            dtype),
        "len": jnp.zeros((), jnp.int32),
    }
    if tail:
        cache["tail_ssm"] = stack(mamba2.mamba_init_state(cfg, batch, dtype),
                                  (tail,))
    return cache


@functools.partial(jax.jit, static_argnames=("cfg",))
def zamba_decode_step(params, tokens, cache, cfg):
    x = params["embed"][tokens]
    b = x.shape[0]
    pos = cache["len"]
    sin, cos = L.rope_angles(pos[None].astype(jnp.int32), cfg.hd,
                             cfg.rope_theta)
    sp = params["shared_attn"]

    def m_layer(x, xs):
        bp, st = xs
        y, st_new = mamba2.mamba_step(rms_norm(x, bp["ln"], cfg.norm_eps),
                                      st, bp["mamba"], cfg)
        return x + y, st_new

    def group(x, gxs):
        g_bp, g_st, ck, cv = gxs
        x, st_new = scan_layers(m_layer, x, (g_bp, g_st))
        xn = rms_norm(x, sp["ln1"], cfg.norm_eps)
        k_new, v_new = L.project_kv(xn, sp["attn"], cfg, sin, cos)
        ck = lax.dynamic_update_slice_in_dim(ck, k_new.astype(ck.dtype),
                                             pos, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cv, v_new.astype(cv.dtype),
                                             pos, axis=1)
        h = L.gqa_attention(xn, sp["attn"], cfg, sin=sin, cos=cos,
                            causal=True, offset=pos, kv_len_valid=pos + 1,
                            kv_override=(ck, cv))
        x = x + h
        x = x + L.swiglu(rms_norm(x, sp["ln2"], cfg.norm_eps), sp["ffn"])
        return x, (st_new, ck, cv)

    x, (ssm_new, k_new, v_new) = scan_layers(
        group, x, (params["groups"], cache["ssm"], cache["attn_k"],
                   cache["attn_v"]))
    out_cache = {"ssm": ssm_new, "attn_k": k_new, "attn_v": v_new,
                 "len": pos + 1}
    if "tail" in params:
        x, tail_new = scan_layers(m_layer, x, (params["tail"],
                                            cache["tail_ssm"]))
        out_cache["tail_ssm"] = tail_new
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x[:, -1] @ params["lm_head"], out_cache
