from repro.models.model_zoo import ModelApi, build_model, loss_fn

__all__ = ["ModelApi", "build_model", "loss_fn"]
