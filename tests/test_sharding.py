"""Sharded search subsystem tests: partitioner invariants, single-shard
datapath equivalence in-process, and 2/4/8-shard equivalence on a faked
8-device host mesh (subprocess, like the other multi-device tests)."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import (PipelineConfig, build, make_executor,
                        make_sharded_executor, partition_database, search)
from repro.anns.sharding import ShardedExecutor


@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset(jax.random.PRNGKey(0), n=4000, d=32, n_queries=16,
                        k_gt=50, clusters=16)


@pytest.fixture(scope="module")
def index(ds):
    cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                         final_k=5, refine_budget=20)
    return build(jax.random.PRNGKey(1), ds.x, cfg)


def _ledger_dict(cost):
    return {k: (t.accesses, t.bytes) for k, t in cost.ledger.items()}


class TestPartitioner:
    def test_every_row_exactly_once(self, index):
        si = partition_database(index, 4)
        gids = np.asarray(si.gid)
        real = gids[gids >= 0]
        listed = np.asarray(index.ivf.lists)
        members = listed[listed >= 0]
        assert sorted(real.tolist()) == sorted(members.tolist())
        assert len(set(real.tolist())) == real.size

    def test_whole_lists_per_shard(self, index):
        # each global list id appears on exactly one shard, with all its
        # members mapped contiguously into that shard's local rows
        si = partition_database(index, 4)
        list_gid = np.asarray(si.list_gid)
        owners = list_gid[list_gid >= 0]
        assert sorted(owners.tolist()) == list(range(index.ivf.nlist))
        lists_np = np.asarray(index.ivf.lists)
        lens = np.asarray(index.ivf.list_len)
        gid = np.asarray(si.gid)
        local = np.asarray(si.lists)
        for s in range(4):
            for j, li in enumerate(list_gid[s]):
                if li < 0:
                    continue
                rows = local[s, j, :lens[li]]
                assert (rows >= 0).all()
                assert np.array_equal(gid[s, rows], lists_np[li, :lens[li]])

    def test_lpt_balance(self, index):
        # LPT bound: heaviest shard ≤ mean + the largest single list
        si = partition_database(index, 4)
        lens = np.asarray(index.ivf.list_len)
        assert si.shard_rows.sum() == lens.sum()
        assert si.shard_rows.max() <= lens.sum() / 4 + lens.max()

    def test_shards_bounded_by_nlist(self, index):
        with pytest.raises(ValueError, match="nlist"):
            partition_database(index, index.ivf.nlist + 1)


class TestSingleShardEquivalence:
    """shards=1 exercises the full shard_map datapath on one device."""

    def test_matches_unsharded_ids_and_ledger(self, ds, index):
        a, cost_a = search(index, ds.queries, k=5)
        b, cost_b = search(index, ds.queries, k=5, shards=1)
        assert jnp.array_equal(a, b)
        assert _ledger_dict(cost_a) == _ledger_dict(cost_b)

    def test_pallas_backend_through_shard_map(self, ds, index):
        a, _ = search(index, ds.queries, k=5)
        b, _ = search(index, ds.queries, k=5, shards=1, backend="pallas")
        assert jnp.array_equal(a, b)

    def test_micro_batched_sharded_executor(self, ds, index):
        a, cost_a = search(index, ds.queries, k=5)
        ex = make_sharded_executor(index, shards=1, micro_batch=5)
        b, cost_b = ex.search(ds.queries, k=5)
        assert jnp.array_equal(a, b)
        assert _ledger_dict(cost_a) == _ledger_dict(cost_b)

    def test_executor_memoized_per_index(self, index):
        e1 = make_sharded_executor(index, shards=1)
        e2 = make_sharded_executor(index, shards=1)
        assert e1 is e2
        e3 = make_sharded_executor(index, shards=1, backend="pallas")
        # different backend: new executor, shared partitioned index
        assert e3 is not e1 and e3.sharded is e1.sharded

    def test_graph_front_single_shard(self, ds, index):
        """The graph front's shard_map datapath (halo partitioner +
        frontier exchange) matches the unsharded graph front bit-exactly
        at shards=1 — ids AND full ledger."""
        a, cost_a = search(index, ds.queries, k=5, front="graph")
        b, cost_b = search(index, ds.queries, k=5, front="graph", shards=1)
        assert jnp.array_equal(a, b)
        assert _ledger_dict(cost_a) == _ledger_dict(cost_b)

    def test_graph_partitioner_invariants(self, index):
        from repro.anns.sharding import partition_database
        si = partition_database(index, 4, front="graph")
        assert si.front == "graph"
        n = int(index.x.shape[0])
        # every row owned exactly once
        gids = np.asarray(si.gid)
        real = gids[gids >= 0]
        assert sorted(real.tolist()) == list(range(n))
        xs_loc, adj_gid, adj_loc, loc_of = [np.asarray(a)
                                            for a in si.front_db]
        from repro.anns.stages import graph_for
        g = np.asarray(graph_for(index).neighbors)
        for s in range(4):
            rows = np.where(loc_of[s] >= 0)[0]
            # owned adjacency published with global ids, and every edge —
            # owned or halo — resolvable through adj_loc into xs_loc
            assert np.array_equal(adj_gid[s, :rows.size], g[rows])
            assert (adj_loc[s, :rows.size] < xs_loc.shape[1]).all()

    def test_mesh_needs_devices(self, index):
        from repro.launch.mesh import make_search_mesh
        n = len(jax.devices())
        with pytest.raises(ValueError, match="devices"):
            make_search_mesh(n + 1)
        with pytest.raises(ValueError, match="devices"):
            ShardedExecutor.from_index(index, shards=n + 9)


def test_multishard_equivalence_8_devices():
    """Acceptance: 2/4/8 shards on a host-platform mesh return ids
    identical to the unsharded executor for BOTH refine backends, and the
    merged QueryCost bytes per tier equal the unsharded ledger's bytes.
    Runs in a subprocess because the device count must be faked before
    jax initializes."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.anns import Database, PipelineConfig, QueryPlan, build, search
from repro.data import make_dataset
from repro.memory import Tier

ds = make_dataset(jax.random.PRNGKey(0), n=2500, d=32, n_queries=8,
                  k_gt=20, clusters=8)
cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                     final_k=5, refine_budget=20, trq_levels=2)
idx = build(jax.random.PRNGKey(1), ds.x, cfg)
db = Database.wrap(idx)

def tier_bytes(cost):
    out = {}
    for key, t in cost.ledger.items():
        tier = key.rsplit(":", 1)[-1]
        out[tier] = out.get(tier, 0) + t.bytes
    return out

ids_u, cost_u = search(idx, ds.queries, k=5)
res_u = db.query(ds.queries, k=5)
for shards in (2, 4, 8):
    for backend in ("reference", "pallas"):
        ids_s, cost_s = search(idx, ds.queries, k=5, backend=backend,
                               shards=shards)
        assert jnp.array_equal(ids_u, ids_s), (shards, backend)
        assert tier_bytes(cost_u) == tier_bytes(cost_s), (shards, backend)
        assert cost_s.parallel_s, "per-shard ledgers must be folded"
        # slowest lane bounds the batch: merged time within [1/S, 1]x
        for tier in Tier:
            assert cost_s.tier_seconds(tier) <= cost_u.tier_seconds(tier) \
                + 1e-12, (shards, backend, tier)
        # the planned Database surface: same ids, same per-tier bytes,
        # plus the exact distances the legacy tuple surface drops
        res_s = db.query(ds.queries,
                         plan=QueryPlan(shards=shards, backend=backend,
                                        k=5))
        assert jnp.array_equal(ids_u, res_s.ids), (shards, backend)
        assert tier_bytes(cost_u) == tier_bytes(res_s.cost), (shards,
                                                              backend)
        assert np.allclose(np.asarray(res_s.distances),
                           np.asarray(res_u.distances),
                           rtol=1e-5), (shards, backend)
print("MULTISHARD_OK")
"""
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    try:
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             cwd=root, timeout=1500)
    except subprocess.TimeoutExpired:
        # a hang IS the archetypal sharding failure (deadlocked collective)
        # — fail loudly rather than skip the acceptance criterion
        pytest.fail("8-fake-device equivalence subprocess exceeded 1500s "
                    "— suspect a deadlocked collective in the sharded "
                    "datapath")
    assert "MULTISHARD_OK" in out.stdout, out.stderr[-4000:]


def test_graph_multishard_equivalence_8_devices():
    """Acceptance (graph front): the halo-partitioned traversal with
    per-hop frontier exchange returns ids identical to the unsharded graph
    front at 2/4/8 shards for BOTH refine backends, with equal per-tier
    ledger bytes.  Subprocess for the same faked-device reason as the IVF
    test above."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.anns import Database, PipelineConfig, QueryPlan, build, search
from repro.data import make_dataset

ds = make_dataset(jax.random.PRNGKey(0), n=2500, d=32, n_queries=8,
                  k_gt=20, clusters=8)
cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                     final_k=5, refine_budget=20, trq_levels=2)
idx = build(jax.random.PRNGKey(1), ds.x, cfg)
db = Database.wrap(idx)

def tier_bytes(cost):
    out = {}
    for key, t in cost.ledger.items():
        tier = key.rsplit(":", 1)[-1]
        out[tier] = out.get(tier, 0) + t.bytes
    return out

ids_u, cost_u = search(idx, ds.queries, k=5, front="graph")
for shards in (2, 4, 8):
    for backend in ("reference", "pallas"):
        ids_s, cost_s = search(idx, ds.queries, k=5, front="graph",
                               backend=backend, shards=shards)
        assert jnp.array_equal(ids_u, ids_s), (shards, backend)
        assert tier_bytes(cost_u) == tier_bytes(cost_s), (shards, backend)
        assert cost_s.parallel_s, "per-shard ledgers must be folded"
        res_s = db.query(ds.queries,
                         plan=QueryPlan(front="graph", shards=shards,
                                        backend=backend, k=5))
        assert jnp.array_equal(ids_u, res_s.ids), (shards, backend)
print("GRAPH_MULTISHARD_OK")
"""
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    try:
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             cwd=root, timeout=1500)
    except subprocess.TimeoutExpired:
        pytest.fail("8-fake-device graph equivalence subprocess exceeded "
                    "1500s — suspect a deadlocked collective in the "
                    "frontier exchange")
    assert "GRAPH_MULTISHARD_OK" in out.stdout, out.stderr[-4000:]
