"""Tests for the quantization substrate (kmeans / PQ / SQ / RQ)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_embeddings
from repro.quant import kmeans, pq, quantization_error, rq, sq


@pytest.fixture(scope="module")
def embs():
    return make_embeddings(jax.random.PRNGKey(0), 4000, 64, clusters=16)


class TestKMeans:
    def test_reduces_distortion(self, embs):
        key = jax.random.PRNGKey(1)
        cents = kmeans(key, embs, 16, iters=15)
        err = float(quantization_error(embs, cents))
        # random codebook baseline
        rand = embs[jax.random.choice(jax.random.PRNGKey(2), 4000, (16,),
                                      replace=False)]
        err_rand = float(quantization_error(embs, rand))
        assert err < err_rand
        assert err < float(jnp.mean(jnp.sum(embs ** 2, -1)))  # better than 0-codebook

    def test_no_empty_clusters(self, embs):
        cents = kmeans(jax.random.PRNGKey(3), embs, 32, iters=10)
        from repro.quant.kmeans import assign
        counts = np.bincount(np.asarray(assign(embs, cents)), minlength=32)
        assert (counts > 0).all()


class TestPQ:
    def test_roundtrip_shapes_and_error(self, embs):
        cb = pq.train(jax.random.PRNGKey(4), embs, m=8, k=64, iters=10)
        codes = pq.encode(cb, embs)
        assert codes.shape == (4000, 8) and codes.dtype == jnp.uint8
        recon = pq.decode(cb, codes)
        assert recon.shape == embs.shape
        mse = float(jnp.mean(jnp.sum((recon - embs) ** 2, -1)))
        assert mse < float(jnp.mean(jnp.sum(embs ** 2, -1)))

    def test_adc_matches_explicit_distance(self, embs):
        cb = pq.train(jax.random.PRNGKey(5), embs, m=8, k=32, iters=8)
        codes = pq.encode(cb, embs[:200])
        q = embs[300]
        table = pq.adc_table(cb, q)
        d_adc = pq.adc_distances(table, codes)
        recon = pq.decode(cb, codes)
        d_true = jnp.sum((recon - q[None]) ** 2, axis=-1)
        np.testing.assert_allclose(np.asarray(d_adc), np.asarray(d_true),
                                   rtol=1e-4, atol=1e-5)

    def test_adc_preserves_ranking_quality(self, embs):
        cb = pq.train(jax.random.PRNGKey(6), embs, m=16, k=64, iters=10)
        codes = pq.encode(cb, embs)
        q = embs[0] + 0.01
        table = pq.adc_table(cb, q)
        d_adc = np.asarray(pq.adc_distances(table, codes))
        d_true = np.asarray(jnp.sum((embs - q[None]) ** 2, axis=-1))
        top_true = set(np.argsort(d_true)[:10].tolist())
        top_adc = set(np.argsort(d_adc)[:50].tolist())
        assert len(top_true & top_adc) >= 7  # coarse recall@50 ≥ 0.7


class TestSQ:
    @pytest.mark.parametrize("bits", [3, 4, 8])
    def test_roundtrip_error_shrinks_with_bits(self, embs, bits):
        code = sq.sq_encode(embs[:500], bits)
        recon = sq.sq_decode(code)
        err = float(jnp.mean((recon - embs[:500]) ** 2))
        assert err < (1.0 / (1 << bits)) ** 1.0  # loose monotone bound

    def test_storage_model(self):
        # 4-bit SQ on 768-D: 384 B payload (+8 B range) — paper's comparator.
        assert sq.sq_bytes_per_record(768, 4) == 384 + 8
        assert sq.sq_bytes_per_record(768, 3) == 288 + 8


class TestRQ:
    def test_levels_monotone(self, embs):
        rqc, resid = rq.train(jax.random.PRNGKey(7), embs, m=8, k=32,
                              levels=3, iters=8)
        codes = rq.encode(rqc, embs)
        assert codes.shape == (4000, 3, 8)
        errs = []
        for lv in range(1, 4):
            recon = rq.decode(rqc, codes, through_level=lv)
            errs.append(float(jnp.mean(jnp.sum((recon - embs) ** 2, -1))))
        assert errs[1] < errs[0] and errs[2] < errs[1]
        assert float(jnp.mean(jnp.sum(resid ** 2, -1))) == pytest.approx(
            errs[-1], rel=0.05)
