"""flash_decode numerics vs reference attention on 8 fake devices."""

import subprocess
import sys

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.models.flash_decode import flash_decode
from repro.models.layers import attention, repeat_kv

mesh = jax.make_mesh((2, 4), ("data", "model"))
B, S, KV, H, hd = 4, 64, 2, 8, 16
key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 3)
q = jax.random.normal(ks[0], (B, 1, H, hd))
k = jax.random.normal(ks[1], (B, S, KV, hd))
v = jax.random.normal(ks[2], (B, S, KV, hd))
pos = jnp.asarray(37)   # cache filled to 38

with mesh:
    out = jax.jit(lambda q, k, v: flash_decode(
        q, k, v, pos, mesh=mesh, dp_axes=("data",), n_rep=H // KV))(q, k, v)

ref = attention(q, repeat_kv(k, H // KV), repeat_kv(v, H // KV),
                causal=True, offset=pos, kv_len_valid=pos + 1)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)

# sliding-window variant
with mesh:
    outw = jax.jit(lambda q, k, v: flash_decode(
        q, k, v, pos, mesh=mesh, dp_axes=("data",), n_rep=H // KV,
        window=16))(q, k, v)
refw = attention(q, repeat_kv(k, H // KV), repeat_kv(v, H // KV),
                 causal=True, offset=pos, kv_len_valid=pos + 1, window=16)
np.testing.assert_allclose(np.asarray(outw), np.asarray(refw), rtol=2e-5,
                           atol=2e-5)
print("OK")
"""


def test_flash_decode_matches_reference():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                       cwd="/root/repo", timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
