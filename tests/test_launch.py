"""Launch-layer unit tests: sharding specs, roofline parsing, input specs.

(The real multi-pod compile check is launch/dryrun.py — these tests cover
the pure-Python logic so failures localize.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.launch import roofline
from repro.launch.input_specs import (cache_structs, params_structs,
                                      prefill_batch_specs,
                                      train_batch_specs)
from repro.models.model_zoo import build_model


class FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (16, 16)
        size = 256


def _specs(params):
    from repro.launch import shardings as sh
    return sh.param_specs(FakeMesh, params)


class TestParamSpecs:
    @pytest.mark.parametrize("name", sorted(ARCHS))
    def test_divisibility_everywhere(self, name):
        """Every sharded dim must divide by its mesh axes — for all archs."""
        api = build_model(ARCHS[name])
        params = params_structs(api)
        specs = _specs(params)
        sizes = {"data": 16, "model": 16, ("data",): 16}

        flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for (path, leaf), spec in zip(flat_p, flat_s):
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                axs = ax if isinstance(ax, tuple) else (ax,)
                div = 1
                for a in axs:
                    div *= 16
                assert leaf.shape[dim] % div == 0, \
                    (jax.tree_util.keystr(path), leaf.shape, spec)

    def test_large_weights_are_sharded(self):
        api = build_model(ARCHS["qwen2-72b"])
        params = params_structs(api)
        specs = _specs(params)
        flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        for (path, leaf), spec in zip(flat_p, flat_s):
            n = int(np.prod(leaf.shape))
            if n >= 2**24:   # ≥16M params must not be replicated
                assert any(ax is not None for ax in spec), \
                    (jax.tree_util.keystr(path), leaf.shape)


class TestRooflineParsing:
    def test_shape_bytes(self):
        assert roofline.shape_bytes("bf16[256,1024]{1,0}") == 256 * 1024 * 2
        assert roofline.shape_bytes("(f32[8], s32[4])") == 32 + 16
        assert roofline.shape_bytes("token[]") == 0

    def test_collective_parse(self):
        hlo = """
  %ag = bf16[512,1024]{1,0} all-gather(bf16[32,1024]{1,0} %x), dims={0}
  %ar.1 = f32[4096]{0} all-reduce(f32[4096]{0} %y), to_apply=%add
  %rs = f32[256]{0} reduce-scatter(f32[4096]{0} %z), dimensions={0}
  %cp = bf16[64,64]{1,0} collective-permute(bf16[64,64]{1,0} %w)
  %nothing = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)
"""
        stats = roofline.collective_bytes(hlo)
        assert stats.count_by_op == {"all-gather": 1, "all-reduce": 1,
                                     "reduce-scatter": 1,
                                     "collective-permute": 1}
        assert stats.bytes_by_op["all-gather"] == 512 * 1024 * 2
        assert stats.bytes_by_op["all-reduce"] == 4096 * 4 * 2  # ring 2x

    def test_async_start_done_counted_once(self):
        hlo = """
  %ags = bf16[512]{0} all-gather-start(bf16[32]{0} %x), dims={0}
  %agd = bf16[512]{0} all-gather-done(bf16[512]{0} %ags)
"""
        stats = roofline.collective_bytes(hlo)
        assert stats.count_by_op.get("all-gather", 0) == 1

    @given(st.integers(1, 10_000), st.sampled_from(["f32", "bf16", "s8"]))
    @settings(max_examples=20, deadline=None)
    def test_shape_bytes_property(self, n, dt):
        per = {"f32": 4, "bf16": 2, "s8": 1}[dt]
        assert roofline.shape_bytes(f"{dt}[{n}]") == n * per


class TestInputSpecs:
    @pytest.mark.parametrize("name", sorted(ARCHS))
    def test_all_cells_have_structs(self, name):
        cfg = ARCHS[name]
        api = build_model(cfg)
        for sname, shape in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            if shape.kind == "train":
                b = train_batch_specs(cfg, shape)
                assert b["tokens"].shape == (shape.global_batch,
                                             shape.seq_len)
            elif shape.kind == "prefill":
                b = prefill_batch_specs(cfg, shape)
                assert b["tokens"].shape[0] == shape.global_batch
            else:
                c = cache_structs(api, shape.global_batch, shape.seq_len)
                assert jax.tree.leaves(c)   # non-empty, no allocation

    def test_params_structs_no_allocation(self):
        api = build_model(ARCHS["qwen2-72b"])
        tree = params_structs(api)
        total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
        # ~72B params represented abstractly (nothing allocated)
        assert total > 60e9
        assert all(isinstance(l, jax.ShapeDtypeStruct)
                   for l in jax.tree.leaves(tree))


class TestModelFlops:
    def test_train_flops_formula(self):
        cfg = ARCHS["qwen2.5-3b"]
        shape = SHAPES["train_4k"]
        mf = roofline.model_flops_for(cfg, shape)
        assert mf == pytest.approx(6 * cfg.params_count()
                                   * 256 * 4096, rel=1e-6)

    def test_moe_uses_active_params(self):
        cfg = ARCHS["mixtral-8x22b"]
        assert cfg.active_params_count() < 0.45 * cfg.params_count()
