"""End-to-end behaviour tests for the FaTRQ-augmented ANNS system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import (PipelineConfig, baseline_search, build, recall_at_k,
                        search)
from repro.data import make_dataset
from repro.index import graph, ivf
from repro.memory import QueryCost, Tier


@pytest.fixture(scope="module")
def ds():
    return make_dataset(jax.random.PRNGKey(0), n=8000, d=64, n_queries=48,
                        k_gt=100, clusters=32)


@pytest.fixture(scope="module")
def index(ds):
    cfg = PipelineConfig(dim=64, pq_m=8, pq_k=64, nlist=32, nprobe=8,
                         final_k=10, refine_budget=40)
    return build(jax.random.PRNGKey(1), ds.x, cfg)


class TestIVF:
    def test_probe_contains_true_neighbors(self, ds):
        idx = ivf.build(jax.random.PRNGKey(2), ds.x, nlist=32)
        cand = ivf.probe_batch(idx, ds.queries, nprobe=8)
        hit = 0
        for i in range(ds.queries.shape[0]):
            c = set(np.asarray(cand[i]).tolist())
            g = set(np.asarray(ds.gt[i, :10]).tolist())
            hit += len(g & c) / 10
        assert hit / ds.queries.shape[0] > 0.8

    def test_lists_partition_database(self, ds):
        idx = ivf.build(jax.random.PRNGKey(2), ds.x, nlist=32)
        members = np.asarray(idx.lists)
        members = members[members >= 0]
        assert len(np.unique(members)) >= 0.99 * ds.x.shape[0]  # cap loss <1%


class TestGraph:
    def test_beam_search_recall(self, ds):
        g = graph.build(ds.x, degree=16)
        res = graph.search_batch(g, ds.x, ds.queries, iters=48, beam=64)
        rec = recall_at_k(res[:, :10], ds.gt, 10)
        assert rec > 0.8


class TestPipeline:
    def test_recall_vs_ground_truth(self, ds, index):
        # Budget-capped mode (the paper's operating point, Fig. 8): small
        # recall loss allowed in exchange for few SSD fetches.
        pred, _ = search(index, ds.queries, k=10)
        rec = recall_at_k(pred, ds.gt, 10)
        base, _ = baseline_search(index, ds.queries, k=10)
        rec_base = recall_at_k(base, ds.gt, 10)
        assert rec >= rec_base - 0.03

    def test_cauchy_pruning_is_lossless_without_budget_cap(self, ds):
        # With an open budget, provable pruning must match the baseline
        # exactly: only candidates certified outside top-k are dropped.
        cfg = PipelineConfig(dim=64, pq_m=8, pq_k=64, nlist=32, nprobe=8,
                             final_k=10, refine_budget=750)
        idx = build(jax.random.PRNGKey(9), ds.x, cfg)
        pred, cost = search(idx, ds.queries, k=10)
        base, _ = baseline_search(idx, ds.queries, k=10)
        assert recall_at_k(pred, ds.gt, 10) == recall_at_k(base, ds.gt, 10)
        # and pruning still removed a sizable share of SSD fetches
        ssd = sum(t.accesses for k_, t in cost.ledger.items()
                  if k_.endswith("ssd"))
        assert ssd < 0.6 * 750 * ds.queries.shape[0]

    def test_ssd_traffic_reduced(self, ds, index):
        _, cost = search(index, ds.queries, k=10)
        _, cost_base = baseline_search(index, ds.queries, k=10)
        ssd = sum(t.accesses for k_, t in cost.ledger.items()
                  if k_.endswith("ssd"))
        ssd_base = sum(t.accesses for k_, t in cost_base.ledger.items()
                       if k_.endswith("ssd"))
        assert ssd < 0.5 * ssd_base   # paper: ~2.8× fewer refinement fetches

    def test_throughput_improves(self, ds, index):
        _, cost = search(index, ds.queries, k=10)
        _, cost_base = baseline_search(index, ds.queries, k=10)
        assert cost.total_seconds() < cost_base.total_seconds()

    def test_quantile_bound_mode(self, ds):
        cfg = PipelineConfig(dim=64, pq_m=8, pq_k=64, nlist=32, nprobe=8,
                             final_k=10, refine_budget=40, bound="quantile")
        idx = build(jax.random.PRNGKey(3), ds.x, cfg)
        pred, _ = search(idx, ds.queries, k=10)
        assert recall_at_k(pred, ds.gt, 10) > 0.6

    def test_multilevel_trq(self, ds):
        cfg = PipelineConfig(dim=64, pq_m=8, pq_k=64, nlist=32, nprobe=8,
                             final_k=10, refine_budget=40, trq_levels=2)
        idx = build(jax.random.PRNGKey(4), ds.x, cfg)
        pred, cost = search(idx, ds.queries, k=10)
        assert recall_at_k(pred, ds.gt, 10) > 0.6


class TestCostModel:
    def test_overlap_model_is_max_of_latency_and_bandwidth(self):
        # tier_seconds uses max(lat, bw): queue-amortized access latency and
        # streaming transfer fully overlap — the stage is bound by whichever
        # is larger, never their sum.
        spec = QueryCost().model[Tier.CXL]
        # latency-bound: many minimum-grain accesses
        c = QueryCost()
        c.record("s", Tier.CXL, 100_000, 1)
        lat = 100_000 * spec.latency_s / spec.parallelism
        bw = 100_000 * spec.min_grain_B / spec.bandwidth_Bps
        assert lat > bw
        assert c.tier_seconds(Tier.CXL) == pytest.approx(max(lat, bw))
        # bandwidth-bound: few huge transfers
        c2 = QueryCost()
        c2.record("s", Tier.CXL, 10, 10_000_000)
        lat2 = 10 * spec.latency_s / spec.parallelism
        bw2 = 10 * 10_000_000 / spec.bandwidth_Bps
        assert bw2 > lat2
        assert c2.tier_seconds(Tier.CXL) == pytest.approx(max(lat2, bw2))

    def test_tier_ordering(self):
        c = QueryCost()
        c.record("s", Tier.SSD, 100, 4096)
        ssd_t = c.tier_seconds(Tier.SSD)
        c2 = QueryCost()
        c2.record("s", Tier.CXL, 100, 4096)
        assert c2.tier_seconds(Tier.CXL) < ssd_t

    def test_grain_rounding(self):
        c = QueryCost()
        c.record("s", Tier.SSD, 10, 100)   # 100 B reads cost 4 KiB each
        t = [v for k, v in c.ledger.items() if k.endswith("ssd")][0]
        assert t.bytes == 10 * 4096
