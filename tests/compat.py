"""Optional-dependency shims for the test suite.

The container image may lack ``hypothesis``; property-based tests then skip
while the parametrized sweeps in the same modules keep running.  Import
``given``/``settings``/``st`` from here instead of from hypothesis directly.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # noqa: N801 - mimic hypothesis.strategies namespace
        integers = staticmethod(lambda *a, **k: None)
        floats = staticmethod(lambda *a, **k: None)
        booleans = staticmethod(lambda *a, **k: None)
        sampled_from = staticmethod(lambda *a, **k: None)

__all__ = ["given", "settings", "st"]
