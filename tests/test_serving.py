"""Serving engine + RAG loop integration tests, plus the serving-side cost
accounting: ``Retriever.total_cost`` accumulation, ``QueryCost`` merge /
copy round-trips, and the parallel-shard fold (``merge_parallel``) — and
the continuous-batching ``ServingEngine``: bit-identity against sequential
``db.query`` on every layout × backend, result-cache correctness and
streaming invalidation, the admission scheduler under the virtual clock,
and the no-recompile pin for bucket-padded dispatch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import (Database, PipelineConfig, QueryPlan,
                        StreamingConfig, StreamingIndex, build)
from repro.configs import ARCHS
from repro.data import make_dataset
from repro.memory import QueryCost, Tier
from repro.models import build_model
from repro.serving import (Engine, Request, ResultCache, Retriever,
                           ServingEngine, TenantQoS, rag_answer)


@pytest.fixture(scope="module")
def lm():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


class TestEngine:
    def test_batched_decode_shapes(self, lm):
        cfg, api, params = lm
        eng = Engine(api, params, batch=3, max_len=32)
        out = eng.decode(jnp.zeros((3, 1), jnp.int32), steps=5)
        assert out.shape == (3, 5)
        assert eng.stats.tokens == 15
        assert int(eng.cache["len"]) == 5

    def test_greedy_deterministic(self, lm):
        cfg, api, params = lm
        e1 = Engine(api, params, batch=2, max_len=32)
        e2 = Engine(api, params, batch=2, max_len=32)
        seed = jnp.ones((2, 1), jnp.int32)
        assert jnp.array_equal(e1.decode(seed, 6), e2.decode(seed, 6))


class TestRAG:
    def test_round_trip(self, lm):
        cfg, api, params = lm
        d = cfg.d_model
        ds = make_dataset(jax.random.PRNGKey(1), n=3000, d=d, n_queries=2)
        index = build(jax.random.PRNGKey(2), ds.x,
                      PipelineConfig(dim=d, pq_m=16, pq_k=32, nlist=16,
                                     nprobe=4, final_k=5,
                                     refine_budget=20))
        eng = Engine(api, params, batch=2, max_len=32)

        def embed_fn(tokens):
            e = params["embed"][tokens].mean(axis=1)
            return e / jnp.linalg.norm(e, axis=-1, keepdims=True)

        prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 4), 0,
                                     cfg.vocab)
        res = rag_answer(eng, index, embed_fn, prompts, k=5,
                         decode_steps=4)
        assert res.tokens.shape == (2, 4) and res.ids.shape == (2, 5)
        assert res.cost.total_seconds() > 0
        assert res.degraded is False
        assert eng.stats.retrievals == 2


# ------------------------------------------------------- cost accounting


def _cost(stage_tier_traffic, compute=0.0):
    c = QueryCost()
    for stage, tier, accesses, bytes_each in stage_tier_traffic:
        c.record(stage, tier, accesses, bytes_each)
    c.add_compute(compute)
    return c


class TestQueryCostAccounting:
    def test_merge_sums_traffic_and_compute(self):
        a = _cost([("refine", Tier.CXL, 100, 64)], compute=1.0)
        b = _cost([("refine", Tier.CXL, 50, 64),
                   ("rerank", Tier.SSD, 10, 4096)], compute=2.0)
        ta, tb = a.tier_seconds(Tier.CXL), b.tier_seconds(Tier.CXL)
        a.merge(b)
        assert a.ledger["refine:cxl"].accesses == 150
        assert a.ledger["rerank:ssd"].accesses == 10
        assert a.compute_s == 3.0
        # serial semantics: pooled traffic yields summed time
        assert a.tier_seconds(Tier.CXL) == pytest.approx(ta + tb)

    def test_copy_round_trip_is_independent(self):
        a = _cost([("refine", Tier.CXL, 100, 64)], compute=1.0)
        b = a.copy()
        assert b.ledger["refine:cxl"].accesses == 100
        assert b.total_seconds() == a.total_seconds()
        b.record("refine", Tier.CXL, 1, 64)
        b.add_compute(5.0)
        assert a.ledger["refine:cxl"].accesses == 100
        assert a.compute_s == 1.0

    def test_merge_parallel_max_time_sum_bytes(self):
        fast = _cost([("refine", Tier.CXL, 100, 64)], compute=1.0)
        slow = _cost([("refine", Tier.CXL, 300, 64)], compute=2.0)
        t_fast = fast.tier_seconds(Tier.CXL)
        t_slow = slow.tier_seconds(Tier.CXL)
        merged = fast.merge_parallel(slow)
        # bytes/accesses SUM (every lane really moved its bytes) ...
        assert merged.ledger["refine:cxl"].accesses == 400
        assert merged.ledger["refine:cxl"].bytes == 400 * 64
        # ... but time is the slowest lane, not the serial sum
        assert merged.tier_seconds(Tier.CXL) == pytest.approx(t_slow)
        assert merged.tier_seconds(Tier.CXL) < t_fast + t_slow
        assert merged.compute_s == 2.0

    def test_merge_parallel_chains_and_serial_merge_freezes(self):
        lanes = [_cost([("refine", Tier.CXL, n, 64)])
                 for n in (100, 250, 50)]
        t_max = max(c.tier_seconds(Tier.CXL) for c in lanes)
        merged = lanes[0]
        for c in lanes[1:]:
            merged.merge_parallel(c)
        assert merged.tier_seconds(Tier.CXL) == pytest.approx(t_max)
        # a later SERIAL merge (next request batch) adds times again
        before = merged.tier_seconds(Tier.CXL)
        nxt = _cost([("refine", Tier.CXL, 100, 64)])
        t_nxt = nxt.tier_seconds(Tier.CXL)
        merged.merge(nxt)
        assert merged.tier_seconds(Tier.CXL) == pytest.approx(before + t_nxt)

    def test_record_after_parallel_fold_extends_time(self):
        # serial work recorded AFTER a parallel fold (e.g. an unsharded
        # search accumulating into a sharded call's ledger via cost=) must
        # still show up in time, additively on the frozen lane maximum
        a = _cost([("refine", Tier.CXL, 100, 64)])
        a.merge_parallel(_cost([("refine", Tier.CXL, 50, 64)]))
        t_cxl = a.tier_seconds(Tier.CXL)
        ref = _cost([("rerank", Tier.SSD, 10, 4096)])
        a.record("rerank", Tier.SSD, 10, 4096)
        assert a.tier_seconds(Tier.SSD) == \
            pytest.approx(ref.tier_seconds(Tier.SSD))
        assert a.tier_seconds(Tier.CXL) == pytest.approx(t_cxl)

    def test_tier_matching_parses_tier_component(self):
        # a stage name that merely ENDS in a tier string must not alias the
        # tier (the old endswith matching was fragile for colon-free keys)
        from repro.memory import Traffic
        c = QueryCost()
        c.ledger["stage_overssd"] = Traffic(accesses=10, bytes=4096)
        assert c.tier_seconds(Tier.SSD) == 0.0
        c.record("rerank", Tier.SSD, 10, 4096)
        assert c.tier_seconds(Tier.SSD) > 0.0


class TestRetrieverAccounting:
    @pytest.fixture(scope="class")
    def small_index(self):
        ds = make_dataset(jax.random.PRNGKey(7), n=1500, d=16, n_queries=8)
        cfg = PipelineConfig(dim=16, pq_m=4, pq_k=16, nlist=8, nprobe=2,
                             final_k=5, refine_budget=10)
        return ds, build(jax.random.PRNGKey(8), ds.x, cfg)

    def test_total_cost_accumulates_across_calls(self, small_index):
        ds, index = small_index
        r = Retriever(index=index, micro_batch=4)
        _, c1 = r.retrieve(ds.queries, k=5)
        _, c2 = r.retrieve(ds.queries, k=5)
        for key in c1.ledger:
            assert r.total_cost.ledger[key].accesses == \
                c1.ledger[key].accesses + c2.ledger[key].accesses
            assert r.total_cost.ledger[key].bytes == \
                c1.ledger[key].bytes + c2.ledger[key].bytes
        assert r.total_cost.compute_s == pytest.approx(
            c1.compute_s + c2.compute_s)

    def test_sharded_retriever_single_device(self, small_index):
        # shards=1 runs the sharded datapath on this container; per-call
        # ledgers match the unsharded retriever's exactly at S=1
        ds, index = small_index
        plain = Retriever(index=index, micro_batch=None)
        sharded = Retriever(index=index, micro_batch=None, shards=1)
        ids_p, cost_p = plain.retrieve(ds.queries, k=5)
        ids_s, cost_s = sharded.retrieve(ds.queries, k=5)
        assert jnp.array_equal(ids_p, ids_s)
        assert {k: (t.accesses, t.bytes) for k, t in cost_p.ledger.items()} \
            == {k: (t.accesses, t.bytes) for k, t in cost_s.ledger.items()}


# ------------------------------------------------- continuous batching


@pytest.fixture(scope="module")
def serve_ds():
    ds = make_dataset(jax.random.PRNGKey(7), n=1500, d=16, n_queries=16)
    cfg = PipelineConfig(dim=16, pq_m=4, pq_k=16, nlist=8, nprobe=2,
                         final_k=5, refine_budget=10)
    return ds, build(jax.random.PRNGKey(8), ds.x, cfg)


def _ledger(cost):
    return {k: (t.accesses, t.bytes) for k, t in cost.ledger.items()}


class TestServingEngineBitIdentity:
    """The acceptance pin: engine responses — ids, exact distances, and
    the summed traffic ledger — are bit-identical to sequential
    ``db.query`` calls for the same requests, on every layout × backend.
    Batching only regroups per-query-deterministic work; padded rows are
    masked out of candidates and counters by qvalid."""

    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    @pytest.mark.parametrize("layout", ["static", "sharded", "streaming"])
    def test_matches_sequential_query(self, serve_ds, layout, backend):
        ds, index = serve_ds
        idx = (StreamingIndex(index, StreamingConfig(auto_compact=False))
               if layout == "streaming" else index)
        shards = 1 if layout == "sharded" else None
        plan = QueryPlan(backend=backend, shards=shards)
        eng = ServingEngine(idx, plan=plan, max_batch=4, max_wait_us=100.0,
                            cache=ResultCache())
        # distinct queries → the cache is live but every lookup misses,
        # so the datapath runs for all of them (batch sizes vary: the
        # 37us spacing vs the 100us close age coalesces 1-4 per batch)
        reqs = [Request(query=ds.queries[i], arrival_us=i * 37.0, rid=i)
                for i in range(10)]
        resp = eng.run(reqs)
        assert [r.rid for r in resp] == list(range(10))
        assert eng.stats.cache_hits == 0
        assert eng.stats.batches >= 2      # actually coalesced + split
        db = Database.wrap(idx)
        seq_cost = QueryCost()
        for i, r in enumerate(resp):
            ref = db.query(ds.queries[i][None], plan=plan, k=5)
            assert np.array_equal(r.ids, np.asarray(ref.ids[0]))
            assert np.array_equal(r.distances, np.asarray(ref.distances[0]))
            seq_cost.merge(ref.cost)
        assert _ledger(eng.total_cost) == _ledger(seq_cost)

    def test_overlap_off_same_results(self, serve_ds):
        ds, index = serve_ds
        resp_ov = ServingEngine(index, max_batch=4, overlap=True).serve(
            ds.queries[:8], k=5)
        resp_sr = ServingEngine(index, max_batch=4, overlap=False).serve(
            ds.queries[:8], k=5)
        for a, b in zip(resp_ov, resp_sr):
            assert np.array_equal(a.ids, b.ids)
            assert np.array_equal(a.distances, b.distances)


class TestResultCache:
    def test_hit_miss_accounting_and_bit_identity(self, serve_ds):
        ds, index = serve_ds
        cache = ResultCache()
        eng = ServingEngine(index, max_batch=4, max_wait_us=50.0,
                            cache=cache)
        first = eng.serve(ds.queries[:4], k=5)
        assert (cache.stats.misses, cache.stats.hits,
                cache.stats.inserts) == (4, 0, 4)
        second = eng.serve(ds.queries[:4], k=5)
        assert cache.stats.hits == 4 and cache.stats.misses == 4
        for a, b in zip(first, second):
            assert not a.cache_hit and b.cache_hit
            assert b.cost is None and b.batch is None
            assert np.array_equal(a.ids, b.ids)
            assert np.array_equal(a.distances, b.distances)
        # hits never re-enter the datapath: no new batches were formed
        assert eng.stats.batches == 1

    def test_lru_eviction(self):
        cache = ResultCache(capacity=2)
        for tag in (b"a", b"b", b"c"):
            cache.insert(tag, "plan", 0, np.arange(3), np.arange(3.0))
        assert len(cache) == 2 and cache.stats.evictions == 1
        assert cache.lookup(b"a", "plan", 0) is None       # evicted (LRU)
        assert cache.lookup(b"c", "plan", 0) is not None

    def test_plan_and_generation_partition_keys(self):
        cache = ResultCache()
        cache.insert(b"q", "planA", 0, np.arange(3), np.arange(3.0))
        assert cache.lookup(b"q", "planB", 0) is None
        assert cache.lookup(b"q", "planA", 1) is None
        assert cache.lookup(b"q", "planA", 0) is not None

    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_streaming_mutations_invalidate(self, serve_ds, backend):
        ds, index = serve_ds
        st = StreamingIndex(index, StreamingConfig(auto_compact=False))
        cache = ResultCache()
        eng = ServingEngine(st, plan=QueryPlan(backend=backend),
                            max_batch=4, max_wait_us=50.0, cache=cache)

        def warm():
            eng.serve(ds.queries[:4], k=5)
            assert len(cache) >= 4

        warm()
        inv0 = cache.stats.invalidations
        gids = st.insert(ds.queries[:2])
        assert len(cache) == 0
        assert cache.stats.invalidations > inv0
        # post-mutation serves are fresh misses, then hits again
        hits0 = cache.stats.hits
        warm()
        assert cache.stats.hits == hits0

        for mutate in (lambda: st.delete(gids[:1]),
                       lambda: st.compact(),
                       lambda: st.rebalance(2)):
            warm()
            mutate()
            assert len(cache) == 0, "mutation must purge stale entries"


class TestScheduler:
    def test_deadline_ordered_admission(self, serve_ds):
        ds, index = serve_ds
        eng = ServingEngine(index, max_batch=2, max_wait_us=100.0)
        # simultaneous arrivals, deadlines reversed w.r.t. rid: EDF must
        # batch (3,2) before (1,0)
        reqs = [Request(query=ds.queries[i], arrival_us=0.0,
                        deadline_us=1000.0 - 100.0 * i, rid=i)
                for i in range(4)]
        eng.run(reqs)
        assert eng.batch_log[0][2] == (3, 2)
        assert eng.batch_log[1][2] == (1, 0)

    def test_close_on_size(self, serve_ds):
        ds, index = serve_ds
        eng = ServingEngine(index, max_batch=4, max_wait_us=10_000.0)
        reqs = [Request(query=ds.queries[i], arrival_us=5.0, rid=i)
                for i in range(4)]
        eng.run(reqs)
        # a full batch closes immediately — no max_wait aging
        assert eng.batch_log == [(0, 5.0, (0, 1, 2, 3))]

    def test_close_on_age(self, serve_ds):
        ds, index = serve_ds
        eng = ServingEngine(index, max_batch=4, max_wait_us=200.0)
        eng.run([Request(query=ds.queries[0], arrival_us=10.0, rid=0)])
        # a lone request waits out max_wait_us, then dispatches
        assert eng.batch_log == [(0, 210.0, (0,))]

    def test_token_bucket_fairness(self, serve_ds):
        ds, index = serve_ds
        qos = {"heavy": TenantQoS(rate_rps=1000.0, burst=2.0)}
        eng = ServingEngine(index, max_batch=4, max_wait_us=100.0, qos=qos)
        reqs = []
        rid = 0
        for i in range(16):            # heavy: 10k rps, 10x its contract
            reqs.append(Request(query=ds.queries[i % 8], tenant="heavy",
                                arrival_us=i * 100.0, rid=rid))
            rid += 1
        for i in range(3):             # light tenant: unthrottled
            reqs.append(Request(query=ds.queries[8 + i], tenant="light",
                                arrival_us=400.0 + i * 300.0, rid=rid))
            rid += 1
        resp = eng.run(reqs)
        assert len(resp) == 19         # degraded ≠ dropped: all progress
        heavy = [r for r in resp if r.tenant == "heavy"]
        light = [r for r in resp if r.tenant == "light"]
        assert not any(r.degraded for r in light)
        assert sum(r.degraded for r in heavy) >= 10   # over-rate → degraded
        assert sum(not r.degraded for r in heavy) >= 2  # burst honored
        # degraded responses are full responses (k results, finite time)
        for r in heavy:
            assert r.ids.shape == (5,)
            assert np.isfinite(r.done_us)

    def test_degraded_runs_reduced_refine_budget(self, serve_ds):
        ds, index = serve_ds
        eng = ServingEngine(index, degrade_factor=2)
        full = eng._class_plan(5, False)
        deg = eng._class_plan(5, True)
        assert deg.refine_budget == max(5, full.refine_budget // 2)
        assert deg.refine_budget < full.refine_budget

    def test_deterministic_batch_boundaries(self, serve_ds):
        ds, index = serve_ds
        rng = np.random.default_rng(3)
        arr = np.cumsum(rng.exponential(80.0, size=12))

        def trace():
            return [Request(query=ds.queries[i % 8],
                            arrival_us=float(arr[i]),
                            deadline_us=float(arr[i]) + 500.0, rid=i)
                    for i in range(12)]

        e1 = ServingEngine(index, max_batch=4, max_wait_us=150.0,
                           cache=ResultCache())
        e2 = ServingEngine(index, max_batch=4, max_wait_us=150.0,
                           cache=ResultCache())
        r1, r2 = e1.run(trace()), e2.run(trace())
        assert e1.batch_log == e2.batch_log
        assert [(r.rid, r.done_us, r.cache_hit) for r in r1] == \
            [(r.rid, r.done_us, r.cache_hit) for r in r2]


class TestBucketNoRecompile:
    def test_bucket_reuse_never_recompiles(self, serve_ds):
        """Satellite pin: once the power-of-two buckets are traced,
        retrieving any batch size reuses them — the jitted stage caches
        stop growing (``Retriever.retrieve`` pads via ``bucket=True``)."""
        from repro.anns import stages
        ds, index = serve_ds
        r = Retriever(index=index, micro_batch=8)
        for n in (5, 3, 2, 1):          # warm buckets 8, 4, 2, 1
            r.retrieve(ds.queries[:n], k=5)
        sizes = (stages._ivf_candidates._cache_size(),
                 stages._reference_refine._cache_size(),
                 stages._rerank_survivors._cache_size())
        for n in (6, 7, 8, 3, 2, 4, 1, 5):   # every bucket re-hit
            r.retrieve(ds.queries[:n], k=5)
        assert (stages._ivf_candidates._cache_size(),
                stages._reference_refine._cache_size(),
                stages._rerank_survivors._cache_size()) == sizes
