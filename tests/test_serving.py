"""Serving engine + RAG loop integration tests."""

import jax
import jax.numpy as jnp
import pytest

from repro.anns import PipelineConfig, build
from repro.configs import ARCHS
from repro.data import make_dataset
from repro.models import build_model
from repro.serving import Engine, rag_answer


@pytest.fixture(scope="module")
def lm():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


class TestEngine:
    def test_batched_decode_shapes(self, lm):
        cfg, api, params = lm
        eng = Engine(api, params, batch=3, max_len=32)
        out = eng.decode(jnp.zeros((3, 1), jnp.int32), steps=5)
        assert out.shape == (3, 5)
        assert eng.stats.tokens == 15
        assert int(eng.cache["len"]) == 5

    def test_greedy_deterministic(self, lm):
        cfg, api, params = lm
        e1 = Engine(api, params, batch=2, max_len=32)
        e2 = Engine(api, params, batch=2, max_len=32)
        seed = jnp.ones((2, 1), jnp.int32)
        assert jnp.array_equal(e1.decode(seed, 6), e2.decode(seed, 6))


class TestRAG:
    def test_round_trip(self, lm):
        cfg, api, params = lm
        d = cfg.d_model
        ds = make_dataset(jax.random.PRNGKey(1), n=3000, d=d, n_queries=2)
        index = build(jax.random.PRNGKey(2), ds.x,
                      PipelineConfig(dim=d, pq_m=16, pq_k=32, nlist=16,
                                     nprobe=4, final_k=5,
                                     refine_budget=20))
        eng = Engine(api, params, batch=2, max_len=32)

        def embed_fn(tokens):
            e = params["embed"][tokens].mean(axis=1)
            return e / jnp.linalg.norm(e, axis=-1, keepdims=True)

        prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 4), 0,
                                     cfg.vocab)
        gen, ids, cost = rag_answer(eng, index, embed_fn, prompts, k=5,
                                    decode_steps=4)
        assert gen.shape == (2, 4) and ids.shape == (2, 5)
        assert cost.total_seconds() > 0
        assert eng.stats.retrievals == 2
