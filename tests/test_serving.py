"""Serving engine + RAG loop integration tests, plus the serving-side cost
accounting: ``Retriever.total_cost`` accumulation, ``QueryCost`` merge /
copy round-trips, and the parallel-shard fold (``merge_parallel``)."""

import jax
import jax.numpy as jnp
import pytest

from repro.anns import PipelineConfig, build
from repro.configs import ARCHS
from repro.data import make_dataset
from repro.memory import QueryCost, Tier
from repro.models import build_model
from repro.serving import Engine, Retriever, rag_answer


@pytest.fixture(scope="module")
def lm():
    cfg = ARCHS["qwen2.5-3b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


class TestEngine:
    def test_batched_decode_shapes(self, lm):
        cfg, api, params = lm
        eng = Engine(api, params, batch=3, max_len=32)
        out = eng.decode(jnp.zeros((3, 1), jnp.int32), steps=5)
        assert out.shape == (3, 5)
        assert eng.stats.tokens == 15
        assert int(eng.cache["len"]) == 5

    def test_greedy_deterministic(self, lm):
        cfg, api, params = lm
        e1 = Engine(api, params, batch=2, max_len=32)
        e2 = Engine(api, params, batch=2, max_len=32)
        seed = jnp.ones((2, 1), jnp.int32)
        assert jnp.array_equal(e1.decode(seed, 6), e2.decode(seed, 6))


class TestRAG:
    def test_round_trip(self, lm):
        cfg, api, params = lm
        d = cfg.d_model
        ds = make_dataset(jax.random.PRNGKey(1), n=3000, d=d, n_queries=2)
        index = build(jax.random.PRNGKey(2), ds.x,
                      PipelineConfig(dim=d, pq_m=16, pq_k=32, nlist=16,
                                     nprobe=4, final_k=5,
                                     refine_budget=20))
        eng = Engine(api, params, batch=2, max_len=32)

        def embed_fn(tokens):
            e = params["embed"][tokens].mean(axis=1)
            return e / jnp.linalg.norm(e, axis=-1, keepdims=True)

        prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 4), 0,
                                     cfg.vocab)
        gen, ids, cost = rag_answer(eng, index, embed_fn, prompts, k=5,
                                    decode_steps=4)
        assert gen.shape == (2, 4) and ids.shape == (2, 5)
        assert cost.total_seconds() > 0
        assert eng.stats.retrievals == 2


# ------------------------------------------------------- cost accounting


def _cost(stage_tier_traffic, compute=0.0):
    c = QueryCost()
    for stage, tier, accesses, bytes_each in stage_tier_traffic:
        c.record(stage, tier, accesses, bytes_each)
    c.add_compute(compute)
    return c


class TestQueryCostAccounting:
    def test_merge_sums_traffic_and_compute(self):
        a = _cost([("refine", Tier.CXL, 100, 64)], compute=1.0)
        b = _cost([("refine", Tier.CXL, 50, 64),
                   ("rerank", Tier.SSD, 10, 4096)], compute=2.0)
        ta, tb = a.tier_seconds(Tier.CXL), b.tier_seconds(Tier.CXL)
        a.merge(b)
        assert a.ledger["refine:cxl"].accesses == 150
        assert a.ledger["rerank:ssd"].accesses == 10
        assert a.compute_s == 3.0
        # serial semantics: pooled traffic yields summed time
        assert a.tier_seconds(Tier.CXL) == pytest.approx(ta + tb)

    def test_copy_round_trip_is_independent(self):
        a = _cost([("refine", Tier.CXL, 100, 64)], compute=1.0)
        b = a.copy()
        assert b.ledger["refine:cxl"].accesses == 100
        assert b.total_seconds() == a.total_seconds()
        b.record("refine", Tier.CXL, 1, 64)
        b.add_compute(5.0)
        assert a.ledger["refine:cxl"].accesses == 100
        assert a.compute_s == 1.0

    def test_merge_parallel_max_time_sum_bytes(self):
        fast = _cost([("refine", Tier.CXL, 100, 64)], compute=1.0)
        slow = _cost([("refine", Tier.CXL, 300, 64)], compute=2.0)
        t_fast = fast.tier_seconds(Tier.CXL)
        t_slow = slow.tier_seconds(Tier.CXL)
        merged = fast.merge_parallel(slow)
        # bytes/accesses SUM (every lane really moved its bytes) ...
        assert merged.ledger["refine:cxl"].accesses == 400
        assert merged.ledger["refine:cxl"].bytes == 400 * 64
        # ... but time is the slowest lane, not the serial sum
        assert merged.tier_seconds(Tier.CXL) == pytest.approx(t_slow)
        assert merged.tier_seconds(Tier.CXL) < t_fast + t_slow
        assert merged.compute_s == 2.0

    def test_merge_parallel_chains_and_serial_merge_freezes(self):
        lanes = [_cost([("refine", Tier.CXL, n, 64)])
                 for n in (100, 250, 50)]
        t_max = max(c.tier_seconds(Tier.CXL) for c in lanes)
        merged = lanes[0]
        for c in lanes[1:]:
            merged.merge_parallel(c)
        assert merged.tier_seconds(Tier.CXL) == pytest.approx(t_max)
        # a later SERIAL merge (next request batch) adds times again
        before = merged.tier_seconds(Tier.CXL)
        nxt = _cost([("refine", Tier.CXL, 100, 64)])
        t_nxt = nxt.tier_seconds(Tier.CXL)
        merged.merge(nxt)
        assert merged.tier_seconds(Tier.CXL) == pytest.approx(before + t_nxt)

    def test_record_after_parallel_fold_extends_time(self):
        # serial work recorded AFTER a parallel fold (e.g. an unsharded
        # search accumulating into a sharded call's ledger via cost=) must
        # still show up in time, additively on the frozen lane maximum
        a = _cost([("refine", Tier.CXL, 100, 64)])
        a.merge_parallel(_cost([("refine", Tier.CXL, 50, 64)]))
        t_cxl = a.tier_seconds(Tier.CXL)
        ref = _cost([("rerank", Tier.SSD, 10, 4096)])
        a.record("rerank", Tier.SSD, 10, 4096)
        assert a.tier_seconds(Tier.SSD) == \
            pytest.approx(ref.tier_seconds(Tier.SSD))
        assert a.tier_seconds(Tier.CXL) == pytest.approx(t_cxl)

    def test_tier_matching_parses_tier_component(self):
        # a stage name that merely ENDS in a tier string must not alias the
        # tier (the old endswith matching was fragile for colon-free keys)
        from repro.memory import Traffic
        c = QueryCost()
        c.ledger["stage_overssd"] = Traffic(accesses=10, bytes=4096)
        assert c.tier_seconds(Tier.SSD) == 0.0
        c.record("rerank", Tier.SSD, 10, 4096)
        assert c.tier_seconds(Tier.SSD) > 0.0


class TestRetrieverAccounting:
    @pytest.fixture(scope="class")
    def small_index(self):
        ds = make_dataset(jax.random.PRNGKey(7), n=1500, d=16, n_queries=8)
        cfg = PipelineConfig(dim=16, pq_m=4, pq_k=16, nlist=8, nprobe=2,
                             final_k=5, refine_budget=10)
        return ds, build(jax.random.PRNGKey(8), ds.x, cfg)

    def test_total_cost_accumulates_across_calls(self, small_index):
        ds, index = small_index
        r = Retriever(index=index, micro_batch=4)
        _, c1 = r.retrieve(ds.queries, k=5)
        _, c2 = r.retrieve(ds.queries, k=5)
        for key in c1.ledger:
            assert r.total_cost.ledger[key].accesses == \
                c1.ledger[key].accesses + c2.ledger[key].accesses
            assert r.total_cost.ledger[key].bytes == \
                c1.ledger[key].bytes + c2.ledger[key].bytes
        assert r.total_cost.compute_s == pytest.approx(
            c1.compute_s + c2.compute_s)

    def test_sharded_retriever_single_device(self, small_index):
        # shards=1 runs the sharded datapath on this container; per-call
        # ledgers match the unsharded retriever's exactly at S=1
        ds, index = small_index
        plain = Retriever(index=index, micro_batch=None)
        sharded = Retriever(index=index, micro_batch=None, shards=1)
        ids_p, cost_p = plain.retrieve(ds.queries, k=5)
        ids_s, cost_s = sharded.retrieve(ds.queries, k=5)
        assert jnp.array_equal(ids_p, ids_s)
        assert {k: (t.accesses, t.bytes) for k, t in cost_p.ledger.items()} \
            == {k: (t.accesses, t.bytes) for k, t in cost_s.ledger.items()}
