"""The closed capability matrix, executed end-to-end.

Iterates every registered (front, layout, backend) triple straight from
``anns.registry`` — NOT a hardcoded list, so a future front/backend lands
in this sweep automatically — plans it through ``Database``/``QueryPlan``,
and runs a real query: no ``PlanError``, non-empty ids, finite distances.
This is the guard that keeps the matrix from silently reopening (a front
dropping a layout from its declaration fails here before any subsystem
test notices).
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import (Database, PipelineConfig, QueryPlan, StreamingConfig,
                        StreamingIndex, build, registry)


@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset(jax.random.PRNGKey(0), n=1500, d=32, n_queries=6,
                        k_gt=20, clusters=8)


@pytest.fixture(scope="module")
def index(ds):
    cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                         final_k=5, refine_budget=20)
    return build(jax.random.PRNGKey(1), ds.x, cfg)


@pytest.fixture(scope="module")
def streaming(index):
    return StreamingIndex(index, StreamingConfig(auto_compact=False))


def _triples():
    return list(itertools.product(registry.front_names(),
                                  registry.LAYOUTS,
                                  registry.backend_names()))


def test_matrix_is_closed():
    """Every registered front and backend declares every layout."""
    for name in registry.front_names():
        assert registry.front_spec(name).layouts == registry.LAYOUTS, name
    for name in registry.backend_names():
        assert registry.backend_spec(name).layouts == registry.LAYOUTS, name


@pytest.mark.parametrize("front,layout,backend", _triples())
def test_every_triple_plans_and_runs(ds, index, streaming, front, layout,
                                     backend):
    if layout == "streaming":
        db, shards = Database.wrap(streaming), None
    elif layout == "sharded":
        db, shards = Database.wrap(index), 1
    else:
        db, shards = Database.wrap(index), None
    plan = QueryPlan(front=front, backend=backend, shards=shards, k=5)
    rp = db.validate(plan)                 # no PlanError
    assert (rp.front, rp.backend) == (front, backend)
    res = db.query(ds.queries, plan=plan)
    ids = np.asarray(res.ids)
    assert ids.shape == (ds.queries.shape[0], 5)
    assert (ids >= 0).all()
    assert np.isfinite(np.asarray(res.distances)).all()
    assert res.cost.ledger, "search must bill a non-empty traffic ledger"
