"""The closed capability matrix, executed end-to-end.

Iterates every registered (front, layout, backend) triple straight from
``anns.registry`` — NOT a hardcoded list, so a future front/backend lands
in this sweep automatically — plans it through ``Database``/``QueryPlan``,
and runs a real query: no ``PlanError``, non-empty ids, finite distances.
This is the guard that keeps the matrix from silently reopening (a front
dropping a layout from its declaration fails here before any subsystem
test notices).
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import (Database, PipelineConfig, QueryPlan, StreamingConfig,
                        StreamingIndex, TieredConfig, TieredIndex, build,
                        registry)
from repro.obs import trace


@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset(jax.random.PRNGKey(0), n=1500, d=32, n_queries=6,
                        k_gt=20, clusters=8)


@pytest.fixture(scope="module")
def index(ds):
    cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                         final_k=5, refine_budget=20)
    return build(jax.random.PRNGKey(1), ds.x, cfg)


@pytest.fixture(scope="module")
def streaming(index):
    return StreamingIndex(index, StreamingConfig(auto_compact=False))


@pytest.fixture(scope="module")
def tiered(ds, index):
    """Tiered layout with ACTIVE hot/cold placement: heat one query batch,
    then rebalance so the hot-scoring and cold-billing paths actually run
    (an all-warm placement would reduce this sweep to the static path)."""
    ti = TieredIndex(index, TieredConfig(hot_rows_frac=0.25,
                                         cold_rows_frac=0.25))
    Database.wrap(ti).query(ds.queries, plan=QueryPlan(front="ivf", k=5))
    assert ti.rebalance_tiers()["changed"]
    return ti


@pytest.fixture(scope="module")
def index_ml(ds):
    """Multi-level TRQ index: exercises the fused kernel's level loop."""
    cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                         final_k=5, refine_budget=20, trq_levels=2)
    return build(jax.random.PRNGKey(2), ds.x, cfg)


@pytest.fixture(scope="module")
def streaming_ml(ds):
    """Multi-level streaming generation with live delta pages, so backend
    parity covers the per-level delta-split counters."""
    cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                         final_k=5, refine_budget=20, trq_levels=2)
    base = build(jax.random.PRNGKey(3), ds.x[:1200], cfg)
    st = StreamingIndex(base, StreamingConfig(auto_compact=False))
    st.insert(ds.x[1200:])
    return st


@pytest.fixture(scope="module")
def tiered_ml(ds, index_ml):
    """Multi-level tiered placement with live hot AND cold lists, so
    backend parity covers the per-level cold-split counters (the tiered
    reuse of the is_delta marking mechanism) in both backends."""
    ti = TieredIndex(index_ml, TieredConfig(hot_rows_frac=0.25,
                                            cold_rows_frac=0.25))
    Database.wrap(ti).query(ds.queries, plan=QueryPlan(front="ivf", k=5))
    assert ti.rebalance_tiers()["changed"]
    return ti


def _ledger_dict(cost):
    return {k: (t.accesses, t.bytes) for k, t in cost.ledger.items()}


def _triples():
    return list(itertools.product(registry.front_names(),
                                  registry.LAYOUTS,
                                  registry.backend_names()))


def test_matrix_is_closed():
    """Every registered front and backend declares every layout."""
    for name in registry.front_names():
        assert registry.front_spec(name).layouts == registry.LAYOUTS, name
    for name in registry.backend_names():
        assert registry.backend_spec(name).layouts == registry.LAYOUTS, name


@pytest.mark.parametrize("front,layout,backend", _triples())
def test_every_triple_plans_and_runs(ds, index, streaming, tiered, front,
                                     layout, backend):
    if layout == "streaming":
        db, shards = Database.wrap(streaming), None
    elif layout == "sharded":
        db, shards = Database.wrap(index), 1
    elif layout == "tiered":
        db, shards = Database.wrap(tiered), None
    else:
        db, shards = Database.wrap(index), None
    plan = QueryPlan(front=front, backend=backend, shards=shards, k=5)
    rp = db.validate(plan)                 # no PlanError
    assert (rp.front, rp.backend) == (front, backend)
    res = db.query(ds.queries, plan=plan)
    ids = np.asarray(res.ids)
    assert ids.shape == (ds.queries.shape[0], 5)
    assert (ids >= 0).all()
    assert np.isfinite(np.asarray(res.distances)).all()
    assert res.cost.ledger, "search must bill a non-empty traffic ledger"


@pytest.mark.parametrize("front,layout",
                         list(itertools.product(registry.front_names(),
                                                registry.LAYOUTS)))
def test_backend_parity_every_front_layout(ds, index_ml, streaming_ml,
                                           tiered_ml, front, layout):
    """The pallas (fused persistent kernel) and reference backends must
    return bit-identical ids and identical per-entry ledger accesses/bytes
    on every front × layout, with multi-level TRQ (2/4/8-shard parity is
    pinned in test_sharding/test_streaming's fake-device subprocesses)."""
    if layout == "streaming":
        db, shards = Database.wrap(streaming_ml), None
    elif layout == "sharded":
        db, shards = Database.wrap(index_ml), 1
    elif layout == "tiered":
        db, shards = Database.wrap(tiered_ml), None
    else:
        db, shards = Database.wrap(index_ml), None
    results = {}
    for backend in registry.backend_names():
        plan = QueryPlan(front=front, backend=backend, shards=shards, k=5)
        results[backend] = db.query(ds.queries, plan=plan)
    a, b = results["reference"], results["pallas"]
    assert jnp.array_equal(a.ids, b.ids)
    assert _ledger_dict(a.cost) == _ledger_dict(b.cost)


# ledger stage-key prefix → the datapath stage span that billed it
# (hot:hbm is scored inside the rerank span; cold:ssd bills the refine
# path's residual stream at SSD rates)
_STAGE_OF = {"coarse": "front", "front": "front", "handoff": "refine",
             "refine": "refine", "delta": "refine", "hot": "rerank",
             "cold": "refine", "rerank": "rerank"}


@pytest.mark.parametrize("front,layout,backend", _triples())
def test_ledger_span_coverage_every_triple(ds, index, streaming, tiered,
                                           front, layout, backend):
    """Observability invariant over the full matrix: with a tracer
    active, every executed stage emitted ≥1 span AND ≥1 ledger entry,
    and the two views map onto each other — a new ledger stage key
    without a span (or a span that bills nothing) fails here.  Results
    must be bit-identical to the untraced run."""
    if layout == "streaming":
        db, shards = Database.wrap(streaming), None
    elif layout == "sharded":
        db, shards = Database.wrap(index), 1
    elif layout == "tiered":
        db, shards = Database.wrap(tiered), None
    else:
        db, shards = Database.wrap(index), None
    plan = QueryPlan(front=front, backend=backend, shards=shards, k=5)
    tr = trace.Tracer()
    with trace.use(tr):
        res = db.query(ds.queries, plan=plan)
    span_names = {s.name for s in tr.spans}
    stages_billed = set()
    for key in res.cost.ledger:
        stage = key.split(":", 1)[0]
        assert stage in _STAGE_OF, f"unmapped ledger stage {key!r}"
        stages_billed.add(_STAGE_OF[stage])
    # every billed stage produced a span...
    assert stages_billed <= span_names, (
        f"ledger stages {sorted(stages_billed - span_names)} have no span")
    # ...and every stage span billed the ledger
    for stage in ("front", "refine", "rerank"):
        if stage in span_names:
            assert stage in stages_billed, f"{stage} span billed nothing"
    assert {"front", "refine", "rerank"} <= span_names
    untraced = db.query(ds.queries, plan=plan)
    assert jnp.array_equal(untraced.ids, res.ids)
    assert jnp.array_equal(untraced.distances, res.distances)
    assert _ledger_dict(untraced.cost) == _ledger_dict(res.cost)


def test_backend_parity_post_compact_streaming(ds):
    """Parity must survive churn + compaction: after deletes, inserts and
    a compact() the two backends still agree on ids and ledger."""
    cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                        final_k=5, refine_budget=20, trq_levels=2)
    base = build(jax.random.PRNGKey(4), ds.x[:1000], cfg)
    st = StreamingIndex(base, StreamingConfig(auto_compact=False))
    st.insert(ds.x[1000:1400])
    st.delete(np.arange(0, 200))
    st.compact()
    assert st.n_delta_rows == 0 and st.n_tombstones == 0
    db = Database.wrap(st)
    results = {}
    for backend in registry.backend_names():
        plan = QueryPlan(front="ivf", backend=backend, k=5)
        results[backend] = db.query(ds.queries, plan=plan)
    a, b = results["reference"], results["pallas"]
    assert jnp.array_equal(a.ids, b.ids)
    assert _ledger_dict(a.cost) == _ledger_dict(b.cost)
