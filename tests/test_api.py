"""Unified Database / QueryPlan API tests.

Covers the PR-5 acceptance matrix: plan validation (every front × backend
× {static, sharded, streaming} either resolves or raises ``PlanError`` at
plan time), shim equivalence (``pipeline.search`` / ``baseline_search`` /
``Retriever.retrieve`` return bit-identical ids and per-tier ledger bytes
to ``Database.query`` on both refine backends), ``SearchResult`` distance
correctness vs brute force on the returned top-k, and the plan-keyed
executor cache with streaming-generation invalidation across
``compact()`` / ``rebalance()``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import (Database, PipelineConfig, PlanError, QueryPlan,
                        StreamingConfig, StreamingIndex, baseline_search,
                        build, partition_database, search)
from repro.anns.executor import FRONT_STAGES, REFINE_BACKENDS
from repro.data import make_dataset
from repro.serving import Retriever


@pytest.fixture(scope="module")
def ds():
    return make_dataset(jax.random.PRNGKey(0), n=2500, d=32, n_queries=8,
                        k_gt=20, clusters=8)


@pytest.fixture(scope="module")
def cfg():
    return PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                          final_k=5, refine_budget=20)


@pytest.fixture(scope="module")
def index(ds, cfg):
    return build(jax.random.PRNGKey(1), ds.x, cfg)


@pytest.fixture(scope="module")
def streaming(ds, cfg):
    """A live mutable index: base prefix + inserted tail + tombstones, so
    the delta-list and tombstone datapaths are exercised.  gid g always
    maps to ds.x[g] (inserts arrive in dataset order)."""
    st = StreamingIndex(build(jax.random.PRNGKey(2), ds.x[:2000], cfg),
                        StreamingConfig(auto_compact=False))
    st.insert(ds.x[2000:])
    st.delete(np.arange(100, 200))
    return st


def _ledger_dict(cost):
    return {k: (t.accesses, t.bytes) for k, t in cost.ledger.items()}


def _brute_dists(ds, queries, ids):
    x = np.asarray(ds.x)
    q = np.asarray(queries)
    return np.sum((x[np.asarray(ids)] - q[:, None, :]) ** 2, axis=-1)


class TestPlanValidation:
    def test_full_capability_matrix(self, index, streaming):
        # the matrix is CLOSED: every registered front × backend × layout
        # resolves without PlanError (both fronts run everywhere since the
        # sharded frontier exchange + online edge insertion landed)
        targets = {"static": (Database.wrap(index), None),
                   "sharded": (Database.wrap(index), 1),
                   "streaming": (Database.wrap(streaming), None)}
        for front in FRONT_STAGES:
            for backend in REFINE_BACKENDS:
                for layout, (db, shards) in targets.items():
                    plan = QueryPlan(front=front, backend=backend,
                                     shards=shards)
                    rp = db.validate(plan)
                    assert rp.front == front
                    assert rp.backend == backend

    def test_pair_error_names_the_pair(self, index):
        # a front artificially restricted to one layout still produces the
        # structured capability error naming the (front, layout) pair
        from repro.anns import registry as reg
        reg.register_front("probe_only", layouts=("static",))
        try:
            with pytest.raises(PlanError) as ei:
                Database.wrap(index).validate(
                    QueryPlan(front="probe_only", shards=1))
            msg = str(ei.value)
            assert "front 'probe_only'" in msg
            assert "'sharded' index layout" in msg
            assert "GRAPH/IVF front" in msg
        finally:
            reg._FRONTS.pop("probe_only", None)

    def test_raises_at_plan_time_not_mid_search(self, index):
        # queries=None would explode inside any stage — PlanError must fire
        # before the executor ever sees them
        with pytest.raises(PlanError):
            Database.wrap(index).query(None, plan=QueryPlan(front="lsh",
                                                            shards=1))

    def test_unknown_names(self, index):
        db = Database.wrap(index)
        with pytest.raises(PlanError, match="front"):
            db.validate(QueryPlan(front="lsh"))
        with pytest.raises(PlanError, match="backend"):
            db.validate(QueryPlan(backend="cuda"))
        with pytest.raises(PlanError, match="mode"):
            db.validate(QueryPlan(mode="exact"))

    def test_plan_error_is_value_error(self):
        # legacy callers catch ValueError from the pre-registry if-chains
        assert issubclass(PlanError, ValueError)

    def test_resolution_fills_config_defaults(self, index, cfg):
        rp = Database.wrap(index).validate(QueryPlan())
        assert rp == QueryPlan(front=cfg.front, backend=cfg.backend,
                               shards=None, k=cfg.final_k,
                               refine_budget=cfg.refine_budget,
                               micro_batch=cfg.micro_batch)

    def test_baseline_mode_static_only(self, streaming, index):
        with pytest.raises(PlanError, match="baseline"):
            Database.wrap(streaming).validate(QueryPlan(mode="baseline"))
        with pytest.raises(PlanError, match="baseline"):
            Database.wrap(index).validate(QueryPlan(shards=1,
                                                    mode="baseline"))

    def test_shims_raise_plan_error(self, ds, index, streaming):
        with pytest.raises(PlanError, match="front"):
            search(index, ds.queries, shards=1, front="lsh")
        with pytest.raises(PlanError, match="front"):
            Retriever(index=streaming, front="lsh").retrieve(ds.queries,
                                                             k=5)

    def test_wrapped_sharded_index_pins_shard_count(self, ds, index):
        from repro.launch.mesh import make_search_mesh
        si = partition_database(index, 1).place(make_search_mesh(1))
        sdb = Database.wrap(si)
        a, _ = search(index, ds.queries, k=5)
        res = sdb.query(ds.queries, k=5)
        assert jnp.array_equal(res.ids, a)
        with pytest.raises(PlanError, match="partitioned"):
            sdb.validate(QueryPlan(shards=2))
        # a wrapped partition serves the front it was cut for — asking for
        # the other front names the mismatch, not a capability violation
        with pytest.raises(PlanError, match="re-partition"):
            sdb.validate(QueryPlan(front="graph"))


class TestShimEquivalence:
    @pytest.mark.parametrize("backend", REFINE_BACKENDS)
    def test_static(self, ds, index, backend):
        ids, cost = search(index, ds.queries, k=5, backend=backend)
        res = Database.wrap(index).query(
            ds.queries, plan=QueryPlan(backend=backend, k=5))
        assert jnp.array_equal(ids, res.ids)
        assert _ledger_dict(cost) == _ledger_dict(res.cost)

    @pytest.mark.parametrize("backend", REFINE_BACKENDS)
    def test_sharded_single_device(self, ds, index, backend):
        ids, cost = search(index, ds.queries, k=5, shards=1,
                           backend=backend)
        res = Database.wrap(index).query(
            ds.queries, plan=QueryPlan(shards=1, backend=backend, k=5))
        assert jnp.array_equal(ids, res.ids)
        assert _ledger_dict(cost) == _ledger_dict(res.cost)

    @pytest.mark.parametrize("backend", REFINE_BACKENDS)
    def test_streaming(self, ds, streaming, backend):
        ids, cost = search(streaming, ds.queries, k=5, backend=backend)
        res = Database.wrap(streaming).query(
            ds.queries, plan=QueryPlan(backend=backend, k=5))
        assert jnp.array_equal(ids, res.ids)
        assert _ledger_dict(cost) == _ledger_dict(res.cost)
        assert "delta:cxl" in res.cost.ledger      # delta path was live

    def test_retriever(self, ds, index):
        r = Retriever(index=index, micro_batch=4)
        ids, cost = r.retrieve(ds.queries, k=5)
        res = Database.wrap(index).query(
            ds.queries, plan=QueryPlan(front="ivf", micro_batch=4), k=5)
        assert jnp.array_equal(ids, res.ids)
        assert _ledger_dict(cost) == _ledger_dict(res.cost)

    def test_baseline(self, ds, index):
        ids, cost = baseline_search(index, ds.queries, k=5)
        res = Database.wrap(index).query(
            ds.queries, plan=QueryPlan(k=5, mode="baseline"))
        assert jnp.array_equal(ids, res.ids)
        assert _ledger_dict(cost) == _ledger_dict(res.cost)

    def test_k_override_rederives_resolved_budget(self, ds, index, cfg):
        import dataclasses as dc
        # reusing an already-resolved plan (result.plan) with a per-call k
        # must NOT keep the budget resolved for the old k: with no config
        # budget pin, k=5 resolves to max(4·5, 32) = 32 and a k=12
        # override must re-derive max(4·12, 32) = 48, not floor the stale
        # 32 at k
        open_idx = dc.replace(index,
                              config=dc.replace(cfg, refine_budget=None))
        db = Database.wrap(open_idx)
        res = db.query(ds.queries, k=5)
        assert res.plan.refine_budget == 32
        res2 = db.query(ds.queries, plan=res.plan, k=12)
        assert res2.plan.refine_budget == 48
        # an explicitly pinned budget survives a k override
        res3 = db.query(ds.queries, plan=QueryPlan(k=5, refine_budget=15),
                        k=12)
        assert res3.plan.refine_budget == 15

    def test_baseline_cost_merges_into_ledger(self, ds, index):
        from repro.memory import QueryCost
        ledger = QueryCost()
        res = Database.wrap(index).query(
            ds.queries, plan=QueryPlan(k=5, mode="baseline"), cost=ledger)
        assert res.cost is ledger
        assert ledger.ledger["rerank:ssd"].accesses > 0

    def test_micro_batch_per_call_override(self, ds, index):
        db = Database.wrap(index)
        a = db.query(ds.queries, k=5)
        b = db.query(ds.queries, k=5, micro_batch=3)   # does not divide 8
        assert jnp.array_equal(a.ids, b.ids)
        assert _ledger_dict(a.cost) == _ledger_dict(b.cost)
        r = Retriever(index=index, micro_batch=None)
        ids, _ = r.retrieve(ds.queries, k=5, micro_batch=3)
        assert jnp.array_equal(ids, a.ids)


class TestDistances:
    def test_static_matches_brute_force(self, ds, index):
        res = Database.wrap(index).query(ds.queries, k=5)
        assert np.allclose(np.asarray(res.distances),
                           _brute_dists(ds, ds.queries, res.ids),
                           rtol=1e-5, atol=1e-4)
        # distances come out sorted ascending (top-k order)
        d = np.asarray(res.distances)
        assert (np.diff(d, axis=1) >= -1e-6).all()

    def test_sharded_matches_static(self, ds, index):
        db = Database.wrap(index)
        a = db.query(ds.queries, k=5)
        b = db.query(ds.queries, plan=QueryPlan(shards=1, k=5))
        assert jnp.array_equal(a.ids, b.ids)
        assert np.allclose(np.asarray(a.distances),
                           np.asarray(b.distances), rtol=1e-5)

    def test_streaming_matches_brute_force(self, ds, streaming):
        # gid g ↔ ds.x[g] by construction of the fixture
        res = Database.wrap(streaming).query(ds.queries, k=5)
        assert np.allclose(np.asarray(res.distances),
                           _brute_dists(ds, ds.queries, res.ids),
                           rtol=1e-5, atol=1e-4)

    def test_baseline_matches_brute_force(self, ds, index):
        res = Database.wrap(index).query(
            ds.queries, plan=QueryPlan(k=5, mode="baseline"))
        assert np.allclose(np.asarray(res.distances),
                           _brute_dists(ds, ds.queries, res.ids),
                           rtol=1e-5, atol=1e-4)


class TestExecutorCache:
    def test_same_plan_same_executor(self, index):
        db = Database.wrap(index)
        assert db.executor_for(QueryPlan()) is db.executor_for(QueryPlan())
        assert db.executor_for(QueryPlan()) is not \
            db.executor_for(QueryPlan(backend="pallas"))
        # k rides through the resolved plan: same k → same executor
        assert db.executor_for(QueryPlan(k=5)) is db.executor_for(
            QueryPlan())

    def test_retriever_reuses_sharded_executor(self, ds, index):
        # the pre-refactor Retriever rebuilt make_sharded_executor state on
        # every retrieve; the plan-keyed cache must hand back ONE object
        r = Retriever(index=index, shards=1, micro_batch=None)
        e1 = r.db.executor_for(r.default_plan())
        r.retrieve(ds.queries, k=5)
        r.retrieve(ds.queries, k=5)
        assert r.db.executor_for(r.default_plan()) is e1

    def test_streaming_generation_invalidation(self, ds, cfg):
        st = StreamingIndex(build(jax.random.PRNGKey(3), ds.x[:2000], cfg),
                            StreamingConfig(auto_compact=False))
        st.insert(ds.x[2000:])
        db = Database.wrap(st)
        plan, splan = QueryPlan(), QueryPlan(shards=1)
        e1, s1 = db.executor_for(plan), db.executor_for(splan)
        ids1, _ = Retriever(index=st, micro_batch=None).retrieve(
            ds.queries, k=5)
        assert db.executor_for(plan) is e1
        assert db.executor_for(splan) is s1

        st.compact()                      # generation bump → invalidate
        e2, s2 = db.executor_for(plan), db.executor_for(splan)
        assert e2 is not e1 and s2 is not s1
        ids2, _ = Retriever(index=st, micro_batch=None).retrieve(
            ds.queries, k=5)
        assert jnp.array_equal(ids1, ids2)    # compaction preserves results

        st.rebalance(2)                   # rebalance bumps generation too
        assert db.executor_for(plan) is not e2
        assert db.executor_for(splan) is not s2

    def test_stale_generations_pruned(self, ds, cfg):
        st = StreamingIndex(build(jax.random.PRNGKey(4), ds.x[:2000], cfg),
                            StreamingConfig(auto_compact=False))
        db = Database.wrap(st)
        for i in range(4):
            st.insert(ds.x[2000 + 100 * i: 2100 + 100 * i])
            db.executor_for(QueryPlan())
        gens = {k[0] for k in db._compiled}
        assert gens == {db.generation}


class TestResultAndRecords:
    def test_result_carries_resolved_plan(self, ds, index, cfg):
        res = Database.wrap(index).query(ds.queries,
                                         plan=QueryPlan(backend="pallas"))
        assert res.plan.backend == "pallas"
        assert res.plan.front == cfg.front
        assert res.plan.k == cfg.final_k
        assert res.plan.refine_budget == cfg.refine_budget

    def test_bench_emit_records_plan(self, ds, index):
        from benchmarks import common
        common.take_records()             # isolate from other state
        res = Database.wrap(index).query(ds.queries, k=5)
        common.emit("api_test_row", 1.0, cost=res.cost, plan=res.plan)
        common.emit("api_test_planless", 1.0)
        recs = common.take_records()
        assert recs[0]["plan"]["front"] == "ivf"
        assert recs[0]["plan"]["k"] == 5
        assert recs[0]["plan"]["refine_budget"] == 20
        assert recs[1]["plan"] is None    # every record carries the field

    def test_rag_answer_rejects_plan_plus_retriever(self, ds, index):
        from repro.serving import rag_answer
        with pytest.raises(ValueError, match="not both"):
            rag_answer(None, index, lambda t: ds.queries, None,
                       retriever=Retriever(index=index),
                       plan=QueryPlan(backend="pallas"))