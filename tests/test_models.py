"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; plus a decode step against the cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data import make_token_batch
from repro.models import build_model, loss_fn

ALL = sorted(ARCHS.keys())


def _batch_for(cfg, key, b=2, s=32):
    batch = make_token_batch(key, b, s, cfg.vocab)
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.enc_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", ALL)
def test_forward_smoke(name):
    cfg = ARCHS[name].reduced()
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    logits, aux = api.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("name", ALL)
def test_train_step_smoke(name):
    cfg = ARCHS[name].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(api, p, batch))(params)
    assert jnp.isfinite(loss)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in flat))
    assert float(gnorm) > 0.0


@pytest.mark.parametrize("name", ALL)
def test_decode_step_smoke(name):
    cfg = ARCHS[name].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = api.init_cache(params, 2, 64)
    if cfg.enc_dec:
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (2, cfg.enc_frames, cfg.d_model))
        cache = api.prefill(params, {"frames": frames}, cache)
    toks = jnp.zeros((2, 1), jnp.int32)
    logits, cache = api.decode_step(params, toks, cache)
    assert logits.shape == (2, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    logits2, cache = api.decode_step(params, toks, cache)
    assert int(cache["len"]) == 2
    assert not bool(jnp.any(jnp.isnan(logits2)))


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward (qwen2.5)."""
    cfg = ARCHS["qwen2.5-3b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab)
    logits_full, _ = api.forward(params, {"tokens": toks})
    cache = api.init_cache(params, 1, 16)
    outs = []
    for t in range(8):
        lg, cache = api.decode_step(params, toks[:, t:t + 1], cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_ssm():
    """Recurrent decode must match the chunked-parallel forward (zamba2 and
    xlstm) — validates the SSD/mLSTM dual forms against each other."""
    for name in ["zamba2-1.2b", "xlstm-1.3b"]:
        cfg = ARCHS[name].reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0,
                                  cfg.vocab)
        logits_full, _ = api.forward(params, {"tokens": toks}, remat=False)
        cache = api.init_cache(params, 1, 16)
        outs = []
        for t in range(8):
            lg, cache = api.decode_step(params, toks[:, t:t + 1], cache)
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec),
                                   np.asarray(logits_full),
                                   rtol=5e-3, atol=5e-3, err_msg=name)


def test_gemma3_local_global_pattern():
    from repro.models.transformer import layer_is_local
    cfg = ARCHS["gemma3-4b"]
    pattern = [layer_is_local(cfg, i) for i in range(12)]
    assert pattern == [True] * 5 + [False] + [True] * 5 + [False]


def test_mrope_reduces_to_rope_for_text():
    from repro.models.layers import mrope_angles, rope_angles
    pos = jnp.arange(16, dtype=jnp.int32)
    sin1, cos1 = rope_angles(pos, 64, 1e4)
    mpos = jnp.stack([pos[None]] * 3, axis=1)    # (1, 3, S) same coords
    sin2, cos2 = mrope_angles(mpos, 64, 1e4)
    np.testing.assert_allclose(np.asarray(sin1), np.asarray(sin2[0]),
                               rtol=1e-5, atol=1e-6)


def test_param_counts_plausible():
    # Full-config parameter counts should be in the right ballpark.
    approx = {"qwen2-72b": 72e9, "mixtral-8x22b": 140e9,
              "qwen2.5-3b": 3e9, "zamba2-1.2b": 1.2e9}
    for name, expect in approx.items():
        n = ARCHS[name].params_count()
        assert 0.4 * expect < n < 2.2 * expect, (name, n, expect)
