"""Unit + property tests for the FaTRQ core (§III of the paper)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from compat import given, settings, st

from repro.core import (calibrate, compute_scalars, decomposed_distance_sq,
                        encode_database, estimate_q_dot_delta,
                        exact_distance_sq, first_order, identity_model,
                        optimal_k, pack_ternary, packed_size,
                        progressive_search, reconstruct,
                        residual_ip_estimate, storage_bytes,
                        ternary_decode_direction, ternary_encode,
                        ternary_inner, unpack_ternary)
from repro.core.ternary import brute_force_optimal


def _rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


# ---------------------------------------------------------------- ternary

class TestTernaryEncode:
    def test_matches_exhaustive_oracle_small_d(self):
        # The O(D log D) optimum must equal the 3^D enumeration (§III-C).
        for seed in range(8):
            delta = _rand((7,), seed)
            tc = ternary_encode(delta)
            oracle = brute_force_optimal(delta)
            e_delta = delta / jnp.linalg.norm(delta)
            ours = float(ternary_inner(tc.code, e_delta))
            best = float(ternary_inner(oracle, e_delta))
            assert ours == pytest.approx(best, rel=1e-6)

    def test_signs_match_input(self):
        delta = _rand((128,), 3)
        tc = ternary_encode(delta)
        nz = np.asarray(tc.code) != 0
        assert np.all(np.sign(np.asarray(delta))[nz] == np.asarray(tc.code)[nz])

    def test_selects_top_magnitudes(self):
        delta = _rand((64,), 4)
        tc = ternary_encode(delta)
        mags = np.abs(np.asarray(delta))
        k = int(tc.k)
        sel = mags[np.asarray(tc.code) != 0]
        dropped = mags[np.asarray(tc.code) == 0]
        assert sel.min() >= dropped.max() - 1e-7
        assert k == (np.asarray(tc.code) != 0).sum()

    def test_rho_is_alignment(self):
        delta = _rand((96,), 5)
        tc = ternary_encode(delta)
        e_d = delta / jnp.linalg.norm(delta)
        e_c = ternary_decode_direction(tc.code)
        assert float(jnp.dot(e_d, e_c)) == pytest.approx(float(tc.rho), abs=1e-6)
        assert 0.0 < float(tc.rho) <= 1.0

    def test_rho_beats_random_projection_floor(self):
        # With optimal k*, alignment should comfortably exceed the 1/sqrt(D)
        # scale of a random sign code for Gaussian residuals.
        delta = _rand((768,), 6)
        tc = ternary_encode(delta)
        assert float(tc.rho) > 2.0 / np.sqrt(768)

    def test_batched_matches_loop(self):
        deltas = _rand((5, 33), 7)
        tc = ternary_encode(deltas)
        for i in range(5):
            tci = ternary_encode(deltas[i])
            np.testing.assert_array_equal(np.asarray(tc.code[i]),
                                          np.asarray(tci.code))

    @given(st.integers(2, 11), st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_property_optimality(self, d, seed):
        delta = np.asarray(_rand((d,), seed % 10_000)) + 1e-3
        tc = ternary_encode(jnp.asarray(delta))
        oracle = brute_force_optimal(jnp.asarray(delta))
        e = delta / np.linalg.norm(delta)
        ours = float(ternary_inner(tc.code, jnp.asarray(e)))
        best = float(ternary_inner(oracle, jnp.asarray(e)))
        assert ours >= best - 1e-6

    def test_optimal_k_monotone_prefix(self):
        mags = jnp.sort(jnp.abs(_rand((50,), 9)))[::-1]
        k, score = optimal_k(mags)
        csum = np.cumsum(np.asarray(mags))
        scores = csum / np.sqrt(np.arange(1, 51))
        assert int(k) == int(np.argmax(scores)) + 1
        assert float(score) == pytest.approx(scores.max(), rel=1e-6)


# ---------------------------------------------------------------- packing

class TestPacking:
    def test_roundtrip(self):
        code = ternary_encode(_rand((768,), 1)).code
        packed = pack_ternary(code)
        assert packed.shape[-1] == 154 and packed.dtype == jnp.uint8
        out = unpack_ternary(packed, 768)
        np.testing.assert_array_equal(np.asarray(code), np.asarray(out))

    @given(st.integers(1, 600), st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_property(self, d, seed):
        rng = np.random.default_rng(seed)
        code = rng.integers(-1, 2, size=(3, d)).astype(np.int8)
        out = unpack_ternary(pack_ternary(jnp.asarray(code)), d)
        np.testing.assert_array_equal(code, np.asarray(out))

    def test_paper_storage_numbers(self):
        # §III-D: 768-D → 768/5 + 8 = 162 bytes; 2.4× smaller than 384 B 4b-SQ.
        assert storage_bytes(768) == 154 + 8 == 162
        assert packed_size(768) == 154
        sq4 = 768 * 4 // 8
        assert sq4 / storage_bytes(768) == pytest.approx(2.37, abs=0.01)

    def test_byte_range_valid_base3(self):
        code = ternary_encode(_rand((1000,), 2)).code
        packed = np.asarray(pack_ternary(code))
        assert packed.max() <= 242  # 3^5 - 1


# ----------------------------------------------------------- decomposition

class TestDecomposition:
    def test_identity_exact(self):
        # ||x−q||² = d̂₀ + ||δ||² + 2⟨x_c,δ⟩ − 2⟨q,δ⟩ must hold exactly.
        q = _rand((256,), 11)
        x = _rand((10, 256), 12)
        x_c = x + 0.1 * _rand((10, 256), 13)
        sc = compute_scalars(x, x_c)
        d0 = jnp.sum((q - x_c) ** 2, axis=-1)
        q_dot = jnp.sum(q * (x - x_c), axis=-1)
        lhs = exact_distance_sq(q, x)
        rhs = decomposed_distance_sq(d0, sc, q_dot)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4)

    def test_first_order_is_unbiased_ish(self):
        # For isotropic residuals the dropped term has zero mean (§III-A).
        q = _rand((64,), 14)
        x = _rand((2000, 64), 15)
        x_c = x + 0.05 * _rand((2000, 64), 16)
        sc = compute_scalars(x, x_c)
        d0 = jnp.sum((q - x_c) ** 2, axis=-1)
        err = np.asarray(first_order(d0, sc) - exact_distance_sq(q, x))
        assert abs(err.mean()) < 0.05 * np.abs(err).max()


# ------------------------------------------------------------- estimator

class TestEstimator:
    def test_identity_weights_with_exact_ip(self):
        q = _rand((128,), 20)
        x = _rand((6, 128), 21)
        x_c = x + 0.1 * _rand((6, 128), 22)
        sc = compute_scalars(x, x_c)
        d0 = jnp.sum((q - x_c) ** 2, axis=-1)
        d_ip_exact = -2.0 * jnp.sum(q * (x - x_c), axis=-1)
        from repro.core.calibration import build_features, predict
        feats = build_features(d0, d_ip_exact, sc.delta_sq, sc.cross)
        pred = predict(identity_model(), feats)
        np.testing.assert_allclose(np.asarray(pred),
                                   np.asarray(exact_distance_sq(q, x)),
                                   rtol=1e-4)

    def test_ternary_estimate_tracks_truth(self):
        q = _rand((768,), 23)
        x = _rand((500, 768), 24)
        x_c = x + 0.2 * _rand((500, 768), 25)
        delta = x - x_c
        tc = ternary_encode(delta)
        est = residual_ip_estimate(q, tc.code, tc.norm, tc.rho)
        true = -2.0 * jnp.sum(q * delta, axis=-1)
        corr = np.corrcoef(np.asarray(est), np.asarray(true))[0, 1]
        # One ternary level on iid Gaussian residuals at D=768 yields
        # corr ≈ 0.885 (rho·⟨e_q,e_code⟩ shrinkage); deeper levels tighten.
        assert corr > 0.85

    def test_cauchy_bound_is_sound(self):
        # |true − est| ≤ margin must hold EXACTLY (it is Cauchy–Schwarz).
        from repro.core.estimator import cauchy_margin
        q = _rand((256,), 26)
        x = _rand((300, 256), 27)
        x_c = x + 0.3 * _rand((300, 256), 28)
        delta = x - x_c
        tc = ternary_encode(delta)
        est = residual_ip_estimate(q, tc.code, tc.norm, tc.rho)
        true = -2.0 * jnp.sum(q * delta, axis=-1)
        margin = cauchy_margin(q, tc.code, tc.norm, tc.rho)
        assert np.all(np.abs(np.asarray(true - est)) <= np.asarray(margin) * (1 + 1e-5) + 1e-5)


# ------------------------------------------------------------------ TRQ

class TestTRQ:
    def _setup(self, n=400, d=128, levels=1, seed=30):
        x = _rand((n, d), seed)
        x_c = x + 0.2 * _rand((n, d), seed + 1)
        codes, raw = encode_database(x, x_c, num_levels=levels)
        return x, x_c, codes, raw

    def test_roundtrip_levels(self):
        x, x_c, codes, raw = self._setup(levels=2)
        from repro.core.trq import unpack_level
        for lv, tc in enumerate(raw):
            np.testing.assert_array_equal(
                np.asarray(unpack_level(codes, lv)), np.asarray(tc.code))

    def test_stacked_estimate_improves_with_levels(self):
        x, x_c, codes, _ = self._setup(levels=3)
        q = _rand((128,), 40)
        true = jnp.sum(q * (x - x_c), axis=-1)
        errs = []
        for lv in range(1, 4):
            est = estimate_q_dot_delta(q, codes, through_level=lv)
            errs.append(float(jnp.mean((est - true) ** 2)))
        assert errs[1] < errs[0] and errs[2] < errs[1]

    def test_calibration_reduces_boundary_mse(self):
        # §III-E: what matters is precision near the top-k decision boundary.
        # Calibrate on boundary pairs, evaluate on FRESH boundary pairs; the
        # calibrated estimator (which uses the ternary d_ip feature) must beat
        # the first-order estimate (which drops −2⟨q,δ⟩ entirely).
        x, x_c, codes, _ = self._setup(n=2000, d=256)
        key = jax.random.PRNGKey(50)
        pair_idx = jax.random.randint(key, (300,), 0, 2000)
        q_samples = x[pair_idx] + 0.5 * _rand((300, 256), 51)
        cal = calibrate(codes, q_samples, x, x_c, pair_idx)

        eval_idx = jax.random.randint(jax.random.PRNGKey(52), (400,), 0, 2000)
        q_eval = x[eval_idx] + 0.5 * _rand((400, 256), 53)
        true = jnp.sum((q_eval - x[eval_idx]) ** 2, axis=-1)

        from repro.core.calibration import build_features, predict
        from repro.core.trq import unpack_level
        sc = codes.scalars
        d0 = jnp.sum((q_eval - x_c[eval_idx]) ** 2, axis=-1)
        code = unpack_level(codes, 0, eval_idx)
        d_ip = jax.vmap(lambda q, c, n, r: residual_ip_estimate(
            q, c[None], n[None], r[None])[0])(
            q_eval, code, sc.norm[eval_idx], sc.rho[eval_idx])
        feats = build_features(d0, d_ip, sc.delta_sq[eval_idx],
                               sc.cross[eval_idx])
        pred_cal = predict(cal.model, feats)
        sc_eval = type(sc)(delta_sq=sc.delta_sq[eval_idx],
                           cross=sc.cross[eval_idx],
                           rho=sc.rho[eval_idx], norm=sc.norm[eval_idx])
        pred_first = first_order(d0, sc_eval)
        mse_cal = float(jnp.mean((pred_cal - true) ** 2))
        mse_first = float(jnp.mean((pred_first - true) ** 2))
        assert mse_cal < mse_first

    def test_progressive_search_prunes_and_keeps_topk(self):
        x, x_c, codes, _ = self._setup(n=1000, d=128)
        q = _rand((128,), 60)
        d0 = jnp.sum((q - x_c) ** 2, axis=-1)
        cand = jnp.arange(1000)
        state = progressive_search(q, d0, codes, cand, k=10, bound="cauchy")
        true = exact_distance_sq(q, x)
        true_top10 = set(np.argsort(np.asarray(true))[:10].tolist())
        alive = set(np.nonzero(np.asarray(state.alive))[0].tolist())
        # soundness: every true top-10 must survive pruning
        assert true_top10 <= alive
        # effectiveness: pruning must drop a majority of candidates
        assert len(alive) < 500

    def test_bytes_per_record(self):
        _, _, codes, _ = self._setup(d=768 if False else 128)
        assert codes.bytes_per_record() == packed_size(128) + 8
