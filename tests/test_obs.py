"""Observability subsystem tests: tracer semantics, zero-cost disabled
path, deterministic exports, metrics registry, and the serving engine's
unified metrics surface.

The load-bearing pins:

* **bit-identity** — tracing on vs off changes NO query result, ledger
  entry, or virtual-clock timing (the per-triple sweep lives in
  ``test_matrix.test_ledger_span_coverage_every_triple``; here the
  serving engine's responses are pinned end-to-end);
* **zero-cost disabled path** — with no tracer active the module-level
  helpers return the shared no-op handle, no spans are recorded, and a
  traced run leaves every stage jit cache untouched (instrumentation is
  host-side only — it can never grow a jit cache);
* **deterministic exports** — the same seeded serving trace exports a
  byte-identical wall-stripped JSONL and Chrome-trace JSON across runs,
  and the Chrome trace shows batch N+1's front overlapping batch N's
  refine on the virtual clock.
"""

import json

import jax
import numpy as np
import pytest

from repro.anns import (Database, PipelineConfig, QueryPlan, StreamingConfig,
                        StreamingIndex, build)
from repro.data import make_dataset
from repro.memory.tiers import TABLE_I, QueryCost, Tier, Traffic
from repro.obs import export, metrics, trace
from repro.serving import Request, ResultCache, ServingEngine, TenantQoS


@pytest.fixture(scope="module")
def ds():
    return make_dataset(jax.random.PRNGKey(0), n=1500, d=32, n_queries=8,
                        k_gt=20, clusters=8)


@pytest.fixture(scope="module")
def index(ds):
    cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                         final_k=5, refine_budget=20, trq_levels=2)
    return build(jax.random.PRNGKey(1), ds.x, cfg)


def _requests(ds, n=24, seed=0):
    # ~40 µs mean inter-arrival: fast enough that consecutive batches
    # queue behind the virtual pipeline units, which is what makes the
    # front/refine overlap visible in the exported trace
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(40.0, size=n))
    pool = np.asarray(ds.queries)
    picks = rng.integers(0, pool.shape[0], size=n)
    return [Request(query=pool[picks[i]],
                    tenant="busy" if i % 3 == 0 else "t0",
                    arrival_us=float(arrivals[i]), rid=i)
            for i in range(n)]


def _engine(index, tracer=None):
    return ServingEngine(index, max_batch=4, max_wait_us=100.0,
                         qos={"busy": TenantQoS(rate_rps=2000.0, burst=2)},
                         cache=ResultCache(capacity=64), tracer=tracer)


# ----------------------------------------------------------- trace core


def test_span_nesting_and_sids():
    tr = trace.Tracer()
    with trace.use(tr):
        with trace.span("a") as ha:
            with trace.span("b"):
                trace.event("e", x=1)
            with trace.span("c"):
                pass
    a, b, e, c = tr.spans
    assert [s.sid for s in tr.spans] == [0, 1, 2, 3]
    assert (a.parent, b.parent, e.parent, c.parent) == (None, 0, 1, 0)
    assert ha.span is a
    assert e.attrs == {"x": 1}
    assert e.wall_start_s == e.wall_end_s           # zero-duration
    assert a.wall_s >= b.wall_s >= 0.0
    assert [s.sid for s in tr.children(0)] == [1, 3]
    assert tr.by_name("b") == [b]


def test_set_attr_after_exit_and_wall_prefix_stripping():
    tr = trace.Tracer()
    with trace.use(tr):
        with trace.span("s", keep=1) as h:
            pass
        h.set_attr("wall_model_drift", 3.5)
        h.set_attrs(model_s=2.0)
    rec = tr.spans[0].to_record(include_wall=False)
    assert rec["attrs"] == {"keep": 1, "model_s": 2.0}
    assert "wall_start_s" not in rec
    full = tr.spans[0].to_record(include_wall=True)
    assert full["attrs"]["wall_model_drift"] == 3.5


def test_virtual_clock_stamping():
    now = {"t": 100.0}
    tr = trace.Tracer(virtual_clock=lambda: now["t"])
    with trace.use(tr):
        with trace.span("s"):
            now["t"] = 250.0
        ev = tr.event("e", virtual_us=999.0)
    s = tr.spans[0]
    assert (s.virtual_start_us, s.virtual_end_us) == (100.0, 250.0)
    assert s.virtual_us == 150.0
    assert ev.virtual_start_us == ev.virtual_end_us == 999.0
    ex = tr.add_span("x", virtual_start_us=10.0, virtual_end_us=20.0)
    assert ex.virtual_us == 10.0 and ex.wall_s is None


def test_disabled_path_is_noop():
    assert trace.active() is None
    assert trace.span("anything", attr=1) is trace.NOOP_SPAN
    assert trace.event("anything") is None
    with trace.span("x") as h:            # no-op context manager
        h.set_attr("a", 1)
        h.set_attrs(b=2)
    assert h.span is None


def test_traced_run_does_not_grow_jit_caches(ds, index):
    """Instrumentation is host-side only: a traced query must not add a
    single jit-cache entry beyond what the untraced warmup compiled."""
    from repro.anns import stages
    db = Database.wrap(index)
    db.query(ds.queries, k=5)             # warm every stage jit untraced
    sizes = (stages._ivf_candidates._cache_size(),
             stages._reference_refine._cache_size(),
             stages._rerank_survivors._cache_size())
    tr = trace.Tracer()
    with trace.use(tr):
        db.query(ds.queries, k=5)
    assert (stages._ivf_candidates._cache_size(),
            stages._reference_refine._cache_size(),
            stages._rerank_survivors._cache_size()) == sizes
    assert tr.by_name("execute") and tr.by_name("refine.l1")


# -------------------------------------------------------------- metrics


def test_counter_gauge_histogram_semantics():
    reg = metrics.MetricsRegistry()
    c = reg.counter("c_total", "a counter", labelnames=("t",))
    c.labels(t="x").inc()
    c.labels(t="x").inc(2.0)
    with pytest.raises(ValueError):
        c.labels(t="x").inc(-1.0)
    with pytest.raises(ValueError):
        c.labels(wrong="x")
    with pytest.raises(ValueError):
        c.inc()                            # labeled metric, unlabeled use
    g = reg.gauge("g")
    g.set(4.5)
    g._default_child().inc(0.5)
    h = reg.histogram("h", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert reg.flat() == {'c_total{t="x"}': 3.0, "g": 5.0,
                          "h_count": 3, "h_sum": 55.5}
    with pytest.raises(ValueError):        # conflicting redeclaration
        reg.gauge("c_total")
    assert reg.counter("c_total", labelnames=("t",)) is c   # idempotent


def test_registry_collectors_and_context():
    reg = metrics.MetricsRegistry()
    reg.add_collector(lambda: reg.gauge("snap").set(7.0))
    assert metrics.active() is metrics.default_registry()
    with metrics.use(reg):
        assert metrics.active() is reg
    assert metrics.active() is metrics.default_registry()
    assert reg.flat()["snap"] == 7.0       # collector ran at export


def test_prometheus_exposition_format():
    reg = metrics.MetricsRegistry()
    reg.counter("req_total", "requests", labelnames=("t",)) \
        .labels(t="a").inc(3)
    h = reg.histogram("lat_us", "latency", buckets=(1.0, 10.0))
    for v in (0.5, 0.7, 5.0, 500.0):
        h.observe(v)
    text = export.prometheus_text(reg)
    lines = text.strip().splitlines()
    assert "# TYPE req_total counter" in lines
    assert '"a"' in text and "req_total{t=\"a\"} 3" in lines
    # histogram buckets are CUMULATIVE, +Inf equals _count
    assert 'lat_us_bucket{le="1"} 2' in lines
    assert 'lat_us_bucket{le="10"} 3' in lines
    assert 'lat_us_bucket{le="+Inf"} 4' in lines
    assert "lat_us_count 4" in lines
    assert "lat_us_sum 506.2" in lines


def test_tierspec_seconds_matches_ledger_fold():
    cost = QueryCost()
    cost.record("refine", Tier.CXL, 1000, 64)
    t = cost.ledger["refine:cxl"]
    assert cost.tier_seconds(Tier.CXL) == \
        TABLE_I[Tier.CXL].seconds(t.accesses, t.bytes)
    assert TABLE_I[Tier.SSD].seconds(0, 0) == 0.0


# ------------------------------------------------- serving, end to end


def test_serving_bit_identical_with_tracing(ds, index):
    r_off = _engine(index).run(_requests(ds))
    tr = trace.Tracer()
    r_on = _engine(index, tracer=tr).run(_requests(ds))
    assert len(r_off) == len(r_on) > 0
    for a, b in zip(r_off, r_on):
        assert a.rid == b.rid
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.distances, b.distances)
        assert (a.done_us, a.admit_us, a.degraded, a.cache_hit) == \
            (b.done_us, b.admit_us, b.degraded, b.cache_hit)
    assert tr.spans


def test_serving_trace_exports_byte_identical(ds, index, tmp_path):
    paths = []
    for run in range(2):
        tr = trace.Tracer()
        _engine(index, tracer=tr).run(_requests(ds))
        p = tmp_path / f"spans_{run}.jsonl"
        export.write_jsonl(tr.spans, str(p), include_wall=False)
        c = tmp_path / f"chrome_{run}.json"
        export.write_chrome_trace(tr.spans, str(c))
        paths.append((p.read_bytes(), c.read_bytes()))
    assert paths[0] == paths[1]


def test_chrome_trace_schema_and_overlap(ds, index):
    tr = trace.Tracer()
    _engine(index, tracer=tr).run(_requests(ds))
    doc = export.chrome_trace(tr.spans)
    events = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in events if e["ph"] == "M"]
    assert {"process_name", "thread_name"} <= {e["name"] for e in meta}
    tids = {e["args"]["name"]: e["tid"] for e in meta
            if e["name"] == "thread_name"}
    assert {"sched", "unit:front", "unit:refine", "query"} <= set(tids)
    for e in events:
        assert e["ph"] in ("M", "X", "i")
        if e["ph"] == "X":
            assert e["dur"] > 0 and e["ts"] >= 0
        if e["ph"] != "M":
            assert "sid" in e["args"]
    json.dumps(doc)                        # schema is JSON-serializable
    # double-buffering: some batch's front interval overlaps another
    # batch's refine interval on the virtual clock
    fronts = [(e["ts"], e["ts"] + e["dur"]) for e in events
              if e["name"] == "serve.front"]
    refines = [(e["ts"], e["ts"] + e["dur"]) for e in events
               if e["name"] == "serve.refine"]
    assert len(fronts) >= 2 and len(refines) >= 2
    assert any(f[0] < r[1] and r[0] < f[1]
               for f in fronts for r in refines), \
        "no front/refine overlap visible in the exported trace"


def test_serving_metrics_unified_flat_dict(ds, index):
    tr = trace.Tracer()
    eng = _engine(index, tracer=tr)
    eng.run(_requests(ds))
    flat = eng.metrics()
    assert flat['serving_requests_total{tenant="busy"}'] > 0
    assert flat['serving_throttled_total{tenant="busy"}'] > 0
    assert flat['serving_stats{field="requests"}'] == eng.stats.requests
    assert flat['serving_stats{field="batches"}'] == eng.stats.batches
    assert flat['serving_cache{field="misses"}'] == eng.cache.stats.misses
    assert flat["serving_queue_wait_us_count"] > 0
    assert flat["serving_batch_occupancy_count"] == eng.stats.batches
    # datapath drift series landed in the ENGINE registry (context-routed)
    assert flat['fatrq_model_drift_ratio_count{stage="refine"}'] > 0
    assert flat['fatrq_model_drift_ratio_count{stage="front"}'] > 0
    text = export.prometheus_text(eng.registry)
    for series in ("serving_queue_wait_us", "serving_batch_occupancy",
                   "serving_cache", "fatrq_model_drift_ratio",
                   "serving_stats"):
        assert series in text


def test_model_drift_only_when_traced(ds, index):
    eng = _engine(index)                   # no tracer
    eng.run(_requests(ds))
    assert not any(k.startswith("fatrq_model_drift")
                   for k in eng.metrics())


def test_streaming_mutation_events_and_metrics(ds, index):
    st = StreamingIndex(index, StreamingConfig(auto_compact=False))
    reg = metrics.MetricsRegistry()
    tr = trace.Tracer()
    with metrics.use(reg), trace.use(tr):
        gids = st.insert(ds.x[:40])
        st.delete(gids[:10])
        st.compact()
    names = [s.name for s in tr.spans]
    assert {"index.insert", "index.delete", "index.compact"} <= set(names)
    ins = tr.by_name("index.insert")[0]
    assert ins.attrs["n"] == 40 and "tombstone_frac" in ins.attrs
    flat = reg.flat()
    assert flat['streaming_mutations_total{op="insert"}'] == 1.0
    assert flat['streaming_mutations_total{op="compact"}'] == 1.0
    assert flat["streaming_tombstone_frac"] == 0.0   # compact dropped them


def test_cache_events(ds, index):
    tr = trace.Tracer()
    eng = _engine(index, tracer=tr)
    q0, q1 = np.asarray(ds.queries[0]), np.asarray(ds.queries[1])
    # q1's dispatch retires q0's in-flight batch (double buffering), so
    # q0's result is cached by the time its repeat arrives at t=5000
    eng.run([Request(query=q0, arrival_us=0.0, rid=0),
             Request(query=q1, arrival_us=300.0, rid=1),
             Request(query=q0, arrival_us=5000.0, rid=2)])
    assert len(tr.by_name("cache.miss")) == 2
    assert len(tr.by_name("cache.hit")) == 1
    assert len(tr.by_name("serve.cache_hit")) == 1


def test_compile_cache_span(ds, index):
    db = Database(index)                   # fresh handle: empty plan cache
    tr = trace.Tracer()
    with trace.use(tr):
        db.query(ds.queries, k=5)
        db.query(ds.queries, k=5)
    probes = tr.by_name("plan.compile")
    assert [p.attrs["cache_hit"] for p in probes] == [False, True]
    assert len(tr.by_name("plan.compile.build")) == 1
