"""Streaming index subsystem tests: incremental encode, delta lists,
tombstones, drift-triggered compaction/rebalancing, churn equivalence
against a from-scratch static rebuild (both backends), and the sharded
path post-rebalance — plus the satellite fixes (vectorized ivf fill with
spill, vectorized recall_at_k)."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import (PipelineConfig, StreamingConfig, StreamingIndex,
                        build, recall_at_k, search)
from repro.core import trq as trq_mod
from repro.index import ivf as ivf_mod
from repro.quant import pq as pq_mod


@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset(jax.random.PRNGKey(0), n=4000, d=32, n_queries=12,
                        k_gt=50, clusters=16)


@pytest.fixture(scope="module")
def base_index(ds):
    cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                         final_k=5, refine_budget=20)
    # build on a prefix; the remainder is the insert stream
    return build(jax.random.PRNGKey(1), ds.x[:3000], cfg)


def fresh(base_index, **kw):
    kw.setdefault("auto_compact", False)
    return StreamingIndex(base_index, StreamingConfig(**kw))


def _ledger_dict(cost):
    return {k: (t.accesses, t.bytes) for k, t in cost.ledger.items()}


def _tier_bytes(cost):
    out = {}
    for key, t in cost.ledger.items():
        tier = key.rsplit(":", 1)[-1]
        out[tier] = out.get(tier, 0) + t.bytes
    return out


# ------------------------------------------------------- satellite fixes


class TestIVFFill:
    def test_no_silent_drop_under_skew(self):
        # all records land in one list — the old loop dropped everything
        # past cap; the fill must spill instead
        ids = np.zeros((100,), np.int64)
        lists, lens, spilled = ivf_mod.fill_lists(ids, nlist=4, cap=10)
        assert lens[0] == 100 and spilled == 90
        assert sorted(lists[0].tolist()) == list(range(100))

    def test_matches_append_order(self):
        rng = np.random.default_rng(0)
        ids = rng.integers(0, 7, size=500)
        lists, lens, spilled = ivf_mod.fill_lists(ids, nlist=7, cap=200)
        assert spilled == 0
        for li in range(7):
            ref = np.nonzero(ids == li)[0]          # append order
            assert np.array_equal(lists[li, :lens[li]], ref)
            assert (lists[li, lens[li]:] == -1).all()

    def test_build_keeps_every_record(self, ds):
        idx = ivf_mod.build(jax.random.PRNGKey(2), ds.x, nlist=16)
        members = np.asarray(idx.lists)
        members = members[members >= 0]
        assert len(np.unique(members)) == ds.x.shape[0]


class TestRecallVectorized:
    def test_matches_set_loop(self):
        rng = np.random.default_rng(1)
        p = rng.integers(0, 40, size=(16, 10))      # duplicates likely
        g = rng.integers(0, 40, size=(16, 10))
        ref = sum(len(set(p[i].tolist()) & set(g[i].tolist()))
                  for i in range(16)) / (16 * 10)
        assert recall_at_k(jnp.asarray(p), jnp.asarray(g), 10) == \
            pytest.approx(ref)

    def test_perfect_and_zero(self):
        a = jnp.arange(20).reshape(2, 10)
        assert recall_at_k(a, a, 10) == 1.0
        assert recall_at_k(a, a + 100, 10) == 0.0


class TestIncrementalEncode:
    def test_encode_rows_bit_identical(self, ds, base_index):
        x = ds.x[:64]
        pq = pq_mod.encode(base_index.codebook, x)
        x_c = pq_mod.decode(base_index.codebook, pq)
        full, _ = trq_mod.encode_database(x, x_c, num_levels=2)
        inc = trq_mod.encode_rows(x, x_c, num_levels=2,
                                  model=base_index.trq.model)
        for lf, li in zip(jax.tree.leaves(full.levels),
                          jax.tree.leaves(inc.levels)):
            assert jnp.array_equal(lf, li)
        for sf, si in zip(full.scalars, inc.scalars):
            assert jnp.array_equal(sf, si)
        assert inc.model is base_index.trq.model

    def test_write_rows_leaves_existing_untouched(self, ds, base_index):
        x = ds.x[:32]
        pq = pq_mod.encode(base_index.codebook, x)
        x_c = pq_mod.decode(base_index.codebook, pq)
        rows = trq_mod.encode_rows(x, x_c)
        before = base_index.trq.levels[0].packed[:100]
        out = trq_mod.write_rows(base_index.trq, rows, 200)
        assert jnp.array_equal(out.levels[0].packed[:100], before)
        assert jnp.array_equal(out.levels[0].packed[200:232],
                               rows.levels[0].packed)
        assert jnp.array_equal(out.scalars.norm[200:232], rows.scalars.norm)

    def test_level_mismatch_rejected(self, ds, base_index):
        x = ds.x[:8]
        pq = pq_mod.encode(base_index.codebook, x)
        x_c = pq_mod.decode(base_index.codebook, pq)
        rows = trq_mod.encode_rows(x, x_c, num_levels=2)
        with pytest.raises(ValueError, match="mismatch"):
            trq_mod.write_rows(base_index.trq, rows, 0)


# --------------------------------------------------------- streaming core


class TestStreamingBasics:
    def test_fresh_wrap_matches_static(self, ds, base_index):
        st = fresh(base_index)
        a, ca = search(base_index, ds.queries, k=5)
        b, cb = st.search(ds.queries, k=5)
        assert jnp.array_equal(a, b)
        assert _ledger_dict(ca) == _ledger_dict(cb)   # no delta entry yet

    def test_insert_is_searchable(self, ds, base_index):
        st = fresh(base_index)
        gids = st.insert(ds.x[3000:3100])
        assert gids.tolist() == list(range(3000, 3100))
        # query AT an inserted vector must retrieve its global id
        q = ds.x[3000:3001]
        ids, cost = st.search(q, k=5)
        assert 3000 in np.asarray(ids)[0].tolist()
        assert any(k.startswith("delta:") for k in cost.ledger)

    def test_delete_tombstones(self, ds, base_index):
        st = fresh(base_index)
        q = ds.x[10:11]
        ids, _ = st.search(q, k=5)
        assert 10 in np.asarray(ids)[0].tolist()
        st.delete([10])
        ids2, _ = st.search(q, k=5)
        assert 10 not in np.asarray(ids2)[0].tolist()
        with pytest.raises(KeyError):
            st.delete([10])                          # already gone

    def test_bad_delete_batch_is_atomic(self, ds, base_index):
        st = fresh(base_index)
        with pytest.raises(KeyError):
            st.delete([11, 12, 10 ** 9])             # unknown id last
        with pytest.raises(KeyError):
            st.delete([13, 13])                      # duplicate in batch
        # nothing was tombstoned — the failed batches left no trace
        assert st.n_tombstones == 0
        ids, _ = st.search(ds.x[11:12], k=5)
        assert 11 in np.asarray(ids)[0].tolist()

    def test_delete_to_empty_with_auto_compact(self, ds, base_index):
        cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=4, nprobe=2,
                             final_k=2, refine_budget=4)
        small = build(jax.random.PRNGKey(5), ds.x[:64], cfg)
        st = StreamingIndex(small, StreamingConfig(auto_compact=True))
        st.delete(np.arange(64))                     # must not crash
        assert st.n_live == 0
        gids = st.insert(ds.x[100:110])              # index stays usable
        ids, _ = st.search(ds.x[100:101], k=2)
        assert int(gids[0]) in np.asarray(ids)[0].tolist()

    def test_gids_stable_across_compaction(self, ds, base_index):
        st = fresh(base_index)
        st.insert(ds.x[3000:3200])
        st.delete(np.arange(0, 500))
        q = ds.x[3100:3101]
        before, _ = st.search(q, k=5)
        st.compact()
        assert st.n_delta_rows == 0 and st.n_tombstones == 0
        after, _ = st.search(q, k=5)
        assert jnp.array_equal(before, after)

    def test_row_store_and_delta_pages_grow(self, ds, base_index):
        st = fresh(base_index, delta_page=8, row_headroom=0.01)
        cap0 = st.cap_rows
        dcap0 = st.delta_lists.shape[1]
        st.insert(ds.x[3000:4000])
        assert st.cap_rows > cap0                    # row store doubled
        assert st.delta_lists.shape[1] > dcap0       # pages spilled
        assert st.n_live == 4000
        ids, _ = st.search(ds.x[3999:4000], k=5)
        assert 3999 in np.asarray(ids)[0].tolist()

    def test_delta_bytes_are_distinct_ledger_entry(self, ds, base_index):
        st = fresh(base_index)
        st.insert(ds.x[3000:3500])
        _, cost = st.search(ds.queries, k=5)
        delta = [k for k in cost.ledger if k.startswith("delta:")]
        assert delta == ["delta:cxl"]
        assert cost.ledger["delta:cxl"].bytes > 0
        # same far-memory rate as base refine traffic
        lay = st.layout
        t = cost.ledger["delta:cxl"]
        assert t.bytes == t.accesses * max(lay.far_bytes, 64)


class TestDeeperLevelDeltaSplit:
    """Level ℓ≥1 survivor traffic for delta-page candidates is billed to
    ``delta:cxl`` (not the shared ``refine:cxl``), identically in both
    refine backends."""

    def test_split_pinned_both_backends(self, ds):
        from repro.anns import registry
        cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=8, nprobe=4,
                             final_k=5, refine_budget=20, trq_levels=2)
        base = build(jax.random.PRNGKey(3), ds.x[:1500], cfg)
        st = fresh(base)
        st.insert(ds.x[1500:1900])

        # counter ground truth straight from the stage contracts
        fs = registry.make_front("ivf", "streaming", st)
        cand = fs.candidates(ds.queries)
        refined = registry.make_backend("reference").refine(
            ds.queries, cand, st.trq, k=5, bound=cfg.bound, z=cfg.z)
        counts = {n: int(v) for n, v in {**cand.counters,
                                         **refined.counters}.items()}
        n_delta = counts["delta_cand"]
        l1, l1d = counts["refine_alive_l1"], counts["refine_alive_l1_delta"]
        assert n_delta > 0 and l1d > 0          # the split is exercised

        ids_ref, cost_ref = st.search(ds.queries, k=5)
        ids_pal, cost_pal = st.search(ds.queries, k=5, backend="pallas")
        assert jnp.array_equal(ids_ref, ids_pal)
        assert _ledger_dict(cost_ref) == _ledger_dict(cost_pal)

        t_delta = cost_ref.ledger["delta:cxl"]
        t_refine = cost_ref.ledger["refine:cxl"]
        assert t_delta.accesses == n_delta + l1d
        assert t_refine.accesses == \
            (counts["front_cand"] - n_delta) + (l1 - l1d)


class TestDrift:
    def test_tombstone_trigger(self, ds, base_index):
        st = fresh(base_index, max_tombstone_frac=0.1)
        assert not st.needs_compaction()
        st.delete(np.arange(400))
        assert st.needs_compaction()
        st.compact()
        assert not st.needs_compaction()

    def test_delta_trigger(self, ds, base_index):
        st = fresh(base_index, max_delta_frac=0.1)
        st.insert(ds.x[3000:3400])
        assert st.needs_compaction()

    def test_lpt_imbalance_trigger(self, ds, base_index):
        st = fresh(base_index, max_delta_frac=10.0)
        st.rebalance(4)
        assert not st.needs_compaction()
        # pile inserts onto the lists co-resident on ONE shard (clones of
        # a member record land on the member's list) until that shard
        # drifts past the LPT bound a fresh partition would restore
        lists0 = np.nonzero(st._assignment == 0)[0][:4]
        seeds = [int(st.base_lists[li, 0]) for li in lists0]
        clones = np.concatenate(
            [np.tile(np.asarray(st.x[r]), (800, 1)) for r in seeds])
        st.insert(clones)
        d = st.drift()
        assert d["shard_imbalance"] > d["lpt_bound"]
        assert st.needs_compaction()
        stats = st.rebalance(4)
        assert st.drift()["shard_imbalance"] <= st.drift()["lpt_bound"]
        assert stats["moved_rows"] >= 0

    def test_imbalance_is_relative_to_fresh_lpt(self, ds, base_index):
        # shard loads are necessarily unequal (16 lists on 3 shards), but
        # right after rebalance the stale assignment IS the fresh one —
        # the metric must read exactly 1.0, not load/OPT-lower-bound,
        # else unbalanceable skew would spin auto_compact forever
        st = fresh(base_index, max_delta_frac=10.0)
        st.rebalance(3)
        d = st.drift()
        assert d["shard_imbalance"] == 1.0
        assert not st.needs_compaction()

    def test_auto_compact_folds(self, ds, base_index):
        st = StreamingIndex(base_index,
                            StreamingConfig(auto_compact=True,
                                            max_delta_frac=0.05))
        st.insert(ds.x[3000:3400])                   # trips the trigger
        assert st.n_delta_rows == 0                  # folded automatically
        assert st.n_live == 3400


class TestChurnEquivalence:
    """Acceptance: after ≥3 interleaved insert/delete/rebalance rounds the
    streaming search equals a from-scratch static rebuild on the surviving
    rows, for both backends, and sharded==unsharded post-rebalance."""

    def test_three_rounds_both_backends(self, ds, base_index):
        st = fresh(base_index)
        rng = np.random.default_rng(7)
        ins = 3000
        for rnd in range(3):
            st.insert(ds.x[ins:ins + 300])
            ins += 300
            live = np.fromiter(st._gid_row.keys(), np.int64)
            st.delete(rng.choice(live, size=200, replace=False))
            if rnd == 1:
                st.rebalance(2)                      # interleaved rebalance

            s_ref, cost_s = st.search(ds.queries, k=5)
            ridx, gid = st.rebuild_static()
            ids_r, cost_r = search(ridx, ds.queries, k=5)
            assert jnp.array_equal(s_ref, jnp.asarray(gid)[ids_r]), rnd
            s_pal, _ = st.search(ds.queries, k=5, backend="pallas")
            assert jnp.array_equal(s_pal, s_ref), rnd
            # bytes moved per tier agree (delta entry folds into cxl)
            assert _tier_bytes(cost_s) == _tier_bytes(cost_r), rnd

    def test_sharded_matches_unsharded_post_rebalance(self, ds, base_index):
        st = fresh(base_index)
        st.insert(ds.x[3000:3600])
        st.delete(np.arange(100, 400))
        st.rebalance(1)
        a, _ = st.search(ds.queries, k=5)
        b, cost_b = st.search(ds.queries, k=5, shards=1)
        assert jnp.array_equal(a, b)
        c, _ = st.search(ds.queries, k=5, shards=1, backend="pallas")
        assert jnp.array_equal(a, c)

    def test_facade_and_retriever_route_streaming(self, ds, base_index):
        from repro.serving import Retriever
        st = fresh(base_index)
        st.insert(ds.x[3000:3200])
        direct, _ = st.search(ds.queries, k=5)
        via_facade, _ = search(st, ds.queries, k=5)
        assert jnp.array_equal(direct, via_facade)
        r = Retriever(index=st, micro_batch=4)
        via_retr, cost = r.retrieve(ds.queries, k=5)
        assert jnp.array_equal(direct, via_retr)
        assert any(k.startswith("delta:") for k in r.total_cost.ledger)
        # the graph front runs on the streaming layout too (closed matrix)
        gr, _ = Retriever(index=st, front="graph").retrieve(ds.queries, k=5)
        gs, _ = search(st, ds.queries, k=5, front="graph")
        assert jnp.array_equal(gr, gs)
        assert gr.shape == (ds.queries.shape[0], 5)


class TestGraphChurnEquivalence:
    """The streaming graph front: online edge insertion, tombstone
    masking, compaction patching — pinned bit-exactly against a static
    rebuild searching the SAME maintained adjacency."""

    def test_interleaved_rounds_both_backends(self, ds, base_index):
        from repro.anns.executor import SearchExecutor
        from repro.index.graph import GraphIndex

        st = fresh(base_index)
        rng = np.random.default_rng(11)
        ins = 3000
        for rnd in range(3):
            st.insert(ds.x[ins:ins + 200])
            ins += 200
            live = np.fromiter(st._gid_row.keys(), np.int64)
            st.delete(rng.choice(live, size=120, replace=False))
            # mid-churn: the front must run (tombstones + delta rows) and
            # never return a dead id
            mid, _ = st.search(ds.queries, k=5, front="graph")
            assert set(np.asarray(mid).ravel().tolist()) <= \
                set(st._gid_row.keys()), rnd
            st.compact()

            ridx, gid = st.rebuild_static()
            gidx = GraphIndex(jnp.asarray(st._graph))
            for be in ("reference", "pallas"):
                a, cost_a = st.search(ds.queries, k=5, front="graph",
                                      backend=be)
                ex = SearchExecutor.from_index(ridx, front="graph",
                                               backend=be,
                                               graph_index=gidx)
                rows, _, cost_b = ex.execute(ds.queries, k=5)
                b = jnp.asarray(gid)[rows]
                assert jnp.array_equal(a, b), (rnd, be)
                assert _tier_bytes(cost_a) == _tier_bytes(cost_b), (rnd, be)

    def test_online_insert_reachability(self, ds, base_index):
        """Inserted rows are wired into the traversal immediately: querying
        each inserted vector at itself through the graph front finds it
        without any compaction.  In-distribution inserts (perturbed copies
        of database rows) — reverse-edge eviction by later far-away inserts
        is expected FreshDiskANN behavior, not a wiring bug."""
        st = fresh(base_index)
        st.search(ds.queries[:1], k=5, front="graph")  # materialize graph
        new = ds.x[:60] + 1e-3
        gids = st.insert(new)
        r, _ = st.search(new, k=5, front="graph")
        hits = [int(g) in np.asarray(r)[i].tolist()
                for i, g in enumerate(gids)]
        assert sum(hits) / len(hits) >= 0.9

    def test_deleted_ids_never_returned(self, ds, base_index):
        st = fresh(base_index)
        st.search(ds.queries[:1], k=5, front="graph")
        gids = st.insert(ds.x[3000:3100])
        st.delete(gids[:50])
        st.delete(np.arange(0, 200))
        r, _ = st.search(ds.queries, k=5, front="graph")
        dead = set(gids[:50].tolist()) | set(range(200))
        assert not (set(np.asarray(r).ravel().tolist()) & dead)

    def test_streaming_graph_sharded_snapshot(self, ds, base_index):
        """shards=S with front="graph" routes the static snapshot through
        the halo-partitioned sharded traversal and maps back to gids."""
        st = fresh(base_index)
        st.insert(ds.x[3000:3300])
        st.compact()
        a, _ = st.search(ds.queries, k=5, front="graph", shards=1)
        assert a.shape == (ds.queries.shape[0], 5)
        assert set(np.asarray(a).ravel().tolist()) <= \
            set(st._gid_row.keys())


def test_streaming_multishard_8_devices():
    """Churned index searched at 2/4/8 shards post-rebalance matches the
    unsharded streaming path (both backends).  Subprocess because the
    device count must be faked before jax initializes — same pattern as
    test_sharding."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.anns import (PipelineConfig, StreamingConfig, StreamingIndex,
                        build, search)
from repro.data import make_dataset

ds = make_dataset(jax.random.PRNGKey(0), n=3000, d=32, n_queries=8,
                  k_gt=20, clusters=8)
cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                     final_k=5, refine_budget=20)
idx = build(jax.random.PRNGKey(1), ds.x[:2400], cfg)
st = StreamingIndex(idx, StreamingConfig(auto_compact=False))
rng = np.random.default_rng(3)
st.insert(ds.x[2400:3000])
live = np.fromiter(st._gid_row.keys(), np.int64)
st.delete(rng.choice(live, size=300, replace=False))
st.rebalance(4)
ids_u, _ = st.search(ds.queries, k=5)
for shards in (2, 4, 8):
    for backend in ("reference", "pallas"):
        ids_s, cost = st.search(ds.queries, k=5, shards=shards,
                                backend=backend)
        assert jnp.array_equal(ids_u, ids_s), (shards, backend)
        assert cost.parallel_s, "per-shard ledgers must be folded"
print("STREAMING_MULTISHARD_OK")
"""
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    try:
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             cwd=root, timeout=1500)
    except subprocess.TimeoutExpired:
        pytest.fail("8-fake-device streaming subprocess exceeded 1500s — "
                    "suspect a deadlocked collective in the sharded "
                    "snapshot path")
    assert "STREAMING_MULTISHARD_OK" in out.stdout, out.stderr[-4000:]
