"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compat import given, settings, st

from repro.core.packing import pack_ternary, packed_size
from repro.core.ternary import ternary_encode
from repro.core import trq as trq_mod
from repro.anns import stages
from repro.kernels import ops as kernel_ops
from repro.kernels import ref
from repro.kernels.ops import (VMEMBudgetError, adc_scores,
                               fused_refine_bounds_batch,
                               fused_refine_scores_batch, refine_scores)


def _setup_refine(c, d, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (c, d))
    x_c = x + 0.2 * jax.random.normal(ks[1], (c, d))
    delta = x - x_c
    tc = ternary_encode(delta)
    packed = pack_ternary(tc.code)
    q = jax.random.normal(ks[2], (d,))
    d0 = jnp.sum((q[None] - x_c) ** 2, axis=-1)
    delta_sq = jnp.sum(delta * delta, axis=-1)
    cross = jnp.sum(x_c * delta, axis=-1)
    w = jnp.asarray([1.0, 1.1, 0.95, 2.1])
    bias = jnp.asarray(0.3)
    return packed, q, d0, delta_sq, cross, tc.norm, tc.rho, w, bias


class TestTernaryRefineKernel:
    @pytest.mark.parametrize("c,d", [(64, 65), (128, 128), (300, 768),
                                     (1000, 1536), (7, 5), (512, 100)])
    def test_matches_ref(self, c, d):
        args = _setup_refine(c, d, seed=c + d)
        out = refine_scores(*args)
        expect = ref.ternary_refine_ref(*args)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_core_estimator(self):
        # The kernel must agree with the system's reference refine path.
        from repro.core.calibration import CalibrationModel
        from repro.core.decomposition import RecordScalars
        from repro.core.estimator import refine_level
        c, d = 200, 256
        packed, q, d0, delta_sq, cross, norm, rho, w, bias = _setup_refine(
            c, d, seed=3)
        out = refine_scores(packed, q, d0, delta_sq, cross, norm, rho, w,
                            bias)
        model = CalibrationModel(w=w, bias=bias,
                                 resid_std=jnp.asarray(0.0))
        scalars = RecordScalars(delta_sq=delta_sq, cross=cross, rho=rho,
                                norm=norm)
        from repro.core.packing import unpack_ternary
        codes = unpack_ternary(packed, d)
        state = refine_level(q, d0, scalars, codes, model, k=10)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(state.est), rtol=2e-5,
                                   atol=2e-5)
        # certified interval identical: lo = est_raw - margin
        np.testing.assert_allclose(np.asarray(out[:, 1] - out[:, 2]),
                                   np.asarray(state.lo), rtol=2e-5,
                                   atol=2e-5)

    @given(st.integers(1, 400), st.integers(2, 900), st.integers(0, 99))
    @settings(max_examples=12, deadline=None)
    def test_property_shapes(self, c, d, seed):
        args = _setup_refine(c, d, seed=seed)
        out = refine_scores(*args)
        expect = ref.ternary_refine_ref(*args)
        assert out.shape == (c, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=3e-5, atol=3e-5)

    # dims not divisible by 5 (packing pad) × candidate counts not divisible
    # by block_c (ops.py row pad): the kernel must agree with the reference
    # estimator path on est, est_raw (→ lo), and margin.
    @pytest.mark.parametrize("c,d,block_c", [(130, 63, 64), (300, 77, 128),
                                             (65, 129, 64), (513, 251, 256)])
    def test_parity_with_estimator_odd_shapes(self, c, d, block_c):
        from repro.core.calibration import CalibrationModel
        from repro.core.decomposition import RecordScalars
        from repro.core.estimator import refine_level
        from repro.core.packing import unpack_ternary

        packed, q, d0, delta_sq, cross, norm, rho, w, bias = _setup_refine(
            c, d, seed=c * d)
        out = refine_scores(packed, q, d0, delta_sq, cross, norm, rho, w,
                            bias, block_c=block_c)
        model = CalibrationModel(w=w, bias=bias, resid_std=jnp.asarray(0.0))
        scalars = RecordScalars(delta_sq=delta_sq, cross=cross, rho=rho,
                                norm=norm)
        state = refine_level(q, d0, scalars, unpack_ternary(packed, d),
                             model, k=10)
        assert out.shape == (c, 3)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(state.est), rtol=2e-5,
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(out[:, 1] - out[:, 2]),
                                   np.asarray(state.lo), rtol=2e-5,
                                   atol=2e-5)


class TestBatchedRefineKernel:
    @pytest.mark.parametrize("nq,c,d,block_c", [(3, 130, 63, 64),
                                                (5, 512, 100, 256),
                                                (1, 7, 11, 64)])
    def test_matches_per_query_kernel(self, nq, c, d, block_c):
        from repro.kernels.ops import refine_scores_batch

        per_query = []
        packed_b, d0_b, dsq_b, cross_b, norm_b, rho_b, q_b = \
            [], [], [], [], [], [], []
        for i in range(nq):
            packed, q, d0, delta_sq, cross, norm, rho, w, bias = \
                _setup_refine(c, d, seed=100 + i)
            per_query.append(refine_scores(packed, q, d0, delta_sq, cross,
                                           norm, rho, w, bias,
                                           block_c=block_c))
            packed_b.append(packed); q_b.append(q); d0_b.append(d0)
            dsq_b.append(delta_sq); cross_b.append(cross)
            norm_b.append(norm); rho_b.append(rho)
        out = refine_scores_batch(jnp.stack(packed_b), jnp.stack(q_b),
                                  jnp.stack(d0_b), jnp.stack(dsq_b),
                                  jnp.stack(cross_b), jnp.stack(norm_b),
                                  jnp.stack(rho_b), w, bias,
                                  block_c=block_c)
        assert out.shape == (nq, c, 3)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.stack(per_query)),
                                   rtol=2e-5, atol=2e-5)


def _setup_trq(seed, levels, n=400, d=24, nq=3, n_cents=8):
    """Calibrated multi-level TRQ problem with the whole database as the
    candidate set (so exact top-k is contained in it)."""
    key = jax.random.PRNGKey(seed)
    kx, kq, kc, kcal, kp = jax.random.split(key, 5)
    x = jax.random.normal(kx, (n, d))
    cents = jax.random.normal(kc, (n_cents, d))
    assign = jnp.argmin(jnp.sum((x[:, None] - cents[None]) ** 2, -1), -1)
    x_c = cents[assign]
    codes, _ = trq_mod.encode_database(x, x_c, num_levels=levels)
    qcal = jax.random.normal(kcal, (64, d))
    pair = jax.random.randint(kp, (64,), 0, n)
    codes = trq_mod.calibrate(codes, qcal, x, x_c, pair)
    qs = jax.random.normal(kq, (nq, d))
    ids = jnp.broadcast_to(jnp.arange(n)[None], (nq, n))
    valid = jnp.ones((nq, n), bool)
    d0 = jnp.sum((x_c[ids] - qs[:, None]) ** 2, -1)
    d_true = jnp.sum((x[ids] - qs[:, None]) ** 2, -1)
    return codes, qs, ids, valid, d0, d_true


def _fused_args(codes, qs, ids, valid, d0, is_delta=None):
    """Assemble the raw fused-wrapper argument tuple from a TRQ problem."""
    sc = codes.scalars
    if is_delta is None:
        is_delta = jnp.zeros_like(valid)
    return (jnp.stack([lv.packed[ids] for lv in codes.levels]), qs, d0,
            sc.delta_sq[ids], sc.cross[ids], sc.norm[ids], sc.rho[ids],
            valid, is_delta,
            jnp.stack([lv.proj[ids] for lv in codes.levels]),
            jnp.stack([lv.norm[ids] for lv in codes.levels]),
            jnp.stack([lv.rho[ids] for lv in codes.levels]),
            codes.model.w, codes.model.bias, codes.model.resid_std, 3.0)


def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", None)
            if inner is not None:
                n += _count_pallas_calls(inner)
    return n


class TestFusedRefineKernel:
    """The persistent multi-level kernel vs the reference refine chain."""

    @pytest.mark.parametrize("bound", ["cauchy", "quantile"])
    @pytest.mark.parametrize("levels", [1, 2, 3])
    def test_matches_reference_backend(self, bound, levels):
        codes, qs, ids, valid, d0, _ = _setup_trq(levels * 17, levels)
        est_r, level_alive = stages._reference_refine(
            qs, d0, ids, valid, codes, k=5, bound=bound, z=3.0)
        est_p, alive_p, counters = stages._pallas_refine(
            qs, d0, ids, valid, None, codes, k=5, bound=bound, z=3.0,
            block_c=64, axis_name=None)
        np.testing.assert_allclose(np.asarray(est_p), np.asarray(est_r),
                                   rtol=3e-5, atol=3e-5)
        assert jnp.array_equal(alive_p, level_alive[-1])
        ref_counters = stages._level_counters(level_alive)
        assert {k2: int(v) for k2, v in counters.items()} == \
            {k2: int(v) for k2, v in ref_counters.items()}

    @pytest.mark.parametrize("bound", ["cauchy", "quantile"])
    def test_bounds_variant_bitwise_matches_onchip(self, bound):
        """The sharded (bounds-emitting) form + the jnp alive chain must be
        BIT-identical to the on-chip pruning form — that is what makes
        sharded and unsharded pallas runs bit-identical."""
        levels, k = 3, 5
        codes, qs, ids, valid, d0, _ = _setup_trq(29, levels)
        est_a, alive_a, _ = stages._pallas_refine(
            qs, d0, ids, valid, None, codes, k=k, bound=bound, z=3.0,
            block_c=64, axis_name=None)
        args = _fused_args(codes, qs, ids, valid, d0)
        est_b, lo, hi = fused_refine_bounds_batch(*args, bound=bound,
                                                  block_c=64)
        alive = valid
        for lv in range(levels):
            tau = stages._topk_threshold_batch(hi[:, lv], alive, k, None)
            alive = alive & (lo[:, lv] <= tau[:, None])
        assert jnp.array_equal(est_a, est_b)
        assert jnp.array_equal(alive_a, alive)

    def test_block_c_invariant(self):
        """Candidate blocking must not change the survivor set or the
        ledger counters (estimates may differ in ulps: XLA picks its f32
        reduction strategy per block shape)."""
        codes, qs, ids, valid, d0, _ = _setup_trq(31, 2)
        outs = [stages._pallas_refine(qs, d0, ids, valid, None, codes, k=5,
                                      bound="cauchy", z=3.0, block_c=bc,
                                      axis_name=None)
                for bc in (64, 128, 512)]
        for est, alive, counters in outs[1:]:
            np.testing.assert_allclose(np.asarray(est),
                                       np.asarray(outs[0][0]),
                                       rtol=1e-6, atol=1e-6)
            assert jnp.array_equal(alive, outs[0][1])
            assert {k2: int(v) for k2, v in counters.items()} == \
                {k2: int(v) for k2, v in outs[0][2].items()}

    def test_delta_survivor_counts(self):
        """The kernel's delta-split counters must equal the mask-chain
        arithmetic the reference backend uses."""
        codes, qs, ids, valid, d0, _ = _setup_trq(37, 3)
        is_delta = jax.random.bernoulli(jax.random.PRNGKey(5), 0.3,
                                        valid.shape)
        _, level_alive = stages._reference_refine(
            qs, d0, ids, valid, codes, k=5, bound="cauchy", z=3.0)
        expect = stages._level_counters(level_alive, is_delta)
        _, _, counters = stages._pallas_refine(
            qs, d0, ids, valid, is_delta, codes, k=5, bound="cauchy",
            z=3.0, block_c=64, axis_name=None)
        assert {k2: int(v) for k2, v in counters.items()} == \
            {k2: int(v) for k2, v in expect.items()}

    @pytest.mark.parametrize("axis_name", [None, "search"])
    def test_single_kernel_launch(self, axis_name):
        """All TRQ levels run as ONE pallas_call per micro-batch — no
        per-level launches, in both the unsharded and sharded forms."""
        codes, qs, ids, valid, d0, _ = _setup_trq(41, 3)
        if axis_name is None:
            fn = lambda *a: stages._pallas_refine(
                *a, None, codes, k=5, bound="cauchy", z=3.0, block_c=64,
                axis_name=None)
            jaxpr = jax.make_jaxpr(fn)(qs, d0, ids, valid)
        else:
            args = _fused_args(codes, qs, ids, valid, d0)
            jaxpr = jax.make_jaxpr(
                lambda *a: fused_refine_bounds_batch(
                    *a, bound="cauchy", block_c=64))(*args)
        assert _count_pallas_calls(jaxpr.jaxpr) == 1

    def test_vmem_budget_named_error(self):
        codes, qs, ids, valid, d0, _ = _setup_trq(43, 2)
        args = _fused_args(codes, qs, ids, valid, d0)
        with pytest.raises(VMEMBudgetError, match="VMEM"):
            fused_refine_scores_batch(*args, k=5, bound="cauchy",
                                      block_c=1 << 22)
        with pytest.raises(VMEMBudgetError, match="VMEM"):
            fused_refine_bounds_batch(*args, bound="cauchy",
                                      block_c=1 << 22)

    def test_interpret_auto_detection(self):
        """Direct kernel calls (no interpret kwarg) must auto-detect the
        backend instead of silently interpreting on TPU."""
        from repro.kernels import ternary_refine as tr
        assert tr._resolve_interpret(None) == (not tr._ON_TPU)
        assert tr._resolve_interpret(True) is True
        assert tr._resolve_interpret(False) is False
        args = _setup_refine(64, 20, seed=9)
        packed, q, d0, delta_sq, cross, norm, rho, w, bias = args
        q_planes = ref.make_query_planes(q, packed.shape[1])
        scalars = jnp.stack([d0, delta_sq, cross, norm, rho] +
                            [jnp.zeros_like(d0)] * 3, axis=-1)
        params = jnp.concatenate(
            [jnp.linalg.norm(q)[None], w, bias[None],
             jnp.zeros((2,))])[None, :]
        out = tr.ternary_refine(packed, q_planes, scalars, params,
                                block_c=64)
        expect = ref.ternary_refine_ref(*args)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)


class TestCertificationSoundness:
    """Early-exit certification property: across bounds, level depths and
    seeds, NO true top-k member (exact L2 over the candidate set) is ever
    pruned by any level's alive mask — fused kernel and reference chain."""

    @given(st.sampled_from(["cauchy", "quantile"]), st.integers(1, 3),
           st.integers(0, 99))
    @settings(max_examples=12, deadline=None)
    def test_no_true_topk_pruned(self, bound, levels, seed):
        k = 5
        codes, qs, ids, valid, d0, d_true = _setup_trq(seed, levels)
        _, top = jax.lax.top_k(-d_true, k)
        _, level_alive = stages._reference_refine(
            qs, d0, ids, valid, codes, k=k, bound=bound, z=3.0)
        for m in level_alive:                      # every level's mask
            assert bool(jnp.all(jnp.take_along_axis(m, top, axis=1)))
        _, alive_p, _ = stages._pallas_refine(
            qs, d0, ids, valid, None, codes, k=k, bound=bound, z=3.0,
            block_c=64, axis_name=None)
        assert bool(jnp.all(jnp.take_along_axis(alive_p, top, axis=1)))
        # the fused kernel's intermediate masks are the bounds variant's
        # alive chain (bit-identical, see TestFusedRefineKernel) — check
        # them level by level as well
        args = _fused_args(codes, qs, ids, valid, d0)
        _, lo, hi = fused_refine_bounds_batch(*args, bound=bound,
                                              block_c=64)
        alive = valid
        for lv in range(levels):
            tau = stages._topk_threshold_batch(hi[:, lv], alive, k, None)
            alive = alive & (lo[:, lv] <= tau[:, None])
            assert bool(jnp.all(jnp.take_along_axis(alive, top, axis=1)))


class TestADCKernel:
    @pytest.mark.parametrize("c,m,k", [(64, 8, 32), (128, 16, 256),
                                       (500, 32, 64), (13, 4, 16),
                                       (256, 96, 256)])
    def test_matches_ref(self, c, m, k):
        key = jax.random.PRNGKey(c + m + k)
        codes = jax.random.randint(key, (c, m), 0, k).astype(jnp.uint8)
        lut = jax.random.uniform(jax.random.fold_in(key, 1), (m, k))
        out = adc_scores(codes, lut)
        expect = ref.pq_adc_ref(codes, lut)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)

    def test_matches_pq_module(self):
        from repro.quant import pq
        from repro.data import make_embeddings
        x = make_embeddings(jax.random.PRNGKey(0), 1000, 64, clusters=8)
        cb = pq.train(jax.random.PRNGKey(1), x, m=8, k=64, iters=5)
        codes = pq.encode(cb, x[:300])
        q = x[500]
        lut = pq.adc_table(cb, q)
        out = adc_scores(codes, lut)
        expect = pq.adc_distances(lut, codes)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-5)

    @given(st.integers(1, 300), st.sampled_from([2, 4, 8, 16]),
           st.sampled_from([16, 64, 256]), st.integers(0, 99))
    @settings(max_examples=10, deadline=None)
    def test_property(self, c, m, k, seed):
        key = jax.random.PRNGKey(seed)
        codes = jax.random.randint(key, (c, m), 0, k).astype(jnp.uint8)
        lut = jax.random.normal(jax.random.fold_in(key, 1), (m, k))
        np.testing.assert_allclose(np.asarray(adc_scores(codes, lut)),
                                   np.asarray(ref.pq_adc_ref(codes, lut)),
                                   rtol=2e-5, atol=2e-5)
