"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compat import given, settings, st

from repro.core.packing import pack_ternary, packed_size
from repro.core.ternary import ternary_encode
from repro.kernels import ref
from repro.kernels.ops import adc_scores, refine_scores


def _setup_refine(c, d, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (c, d))
    x_c = x + 0.2 * jax.random.normal(ks[1], (c, d))
    delta = x - x_c
    tc = ternary_encode(delta)
    packed = pack_ternary(tc.code)
    q = jax.random.normal(ks[2], (d,))
    d0 = jnp.sum((q[None] - x_c) ** 2, axis=-1)
    delta_sq = jnp.sum(delta * delta, axis=-1)
    cross = jnp.sum(x_c * delta, axis=-1)
    w = jnp.asarray([1.0, 1.1, 0.95, 2.1])
    bias = jnp.asarray(0.3)
    return packed, q, d0, delta_sq, cross, tc.norm, tc.rho, w, bias


class TestTernaryRefineKernel:
    @pytest.mark.parametrize("c,d", [(64, 65), (128, 128), (300, 768),
                                     (1000, 1536), (7, 5), (512, 100)])
    def test_matches_ref(self, c, d):
        args = _setup_refine(c, d, seed=c + d)
        out = refine_scores(*args)
        expect = ref.ternary_refine_ref(*args)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_core_estimator(self):
        # The kernel must agree with the system's reference refine path.
        from repro.core.calibration import CalibrationModel
        from repro.core.decomposition import RecordScalars
        from repro.core.estimator import refine_level
        c, d = 200, 256
        packed, q, d0, delta_sq, cross, norm, rho, w, bias = _setup_refine(
            c, d, seed=3)
        out = refine_scores(packed, q, d0, delta_sq, cross, norm, rho, w,
                            bias)
        model = CalibrationModel(w=w, bias=bias,
                                 resid_std=jnp.asarray(0.0))
        scalars = RecordScalars(delta_sq=delta_sq, cross=cross, rho=rho,
                                norm=norm)
        from repro.core.packing import unpack_ternary
        codes = unpack_ternary(packed, d)
        state = refine_level(q, d0, scalars, codes, model, k=10)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(state.est), rtol=2e-5,
                                   atol=2e-5)
        # certified interval identical: lo = est_raw - margin
        np.testing.assert_allclose(np.asarray(out[:, 1] - out[:, 2]),
                                   np.asarray(state.lo), rtol=2e-5,
                                   atol=2e-5)

    @given(st.integers(1, 400), st.integers(2, 900), st.integers(0, 99))
    @settings(max_examples=12, deadline=None)
    def test_property_shapes(self, c, d, seed):
        args = _setup_refine(c, d, seed=seed)
        out = refine_scores(*args)
        expect = ref.ternary_refine_ref(*args)
        assert out.shape == (c, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=3e-5, atol=3e-5)

    # dims not divisible by 5 (packing pad) × candidate counts not divisible
    # by block_c (ops.py row pad): the kernel must agree with the reference
    # estimator path on est, est_raw (→ lo), and margin.
    @pytest.mark.parametrize("c,d,block_c", [(130, 63, 64), (300, 77, 128),
                                             (65, 129, 64), (513, 251, 256)])
    def test_parity_with_estimator_odd_shapes(self, c, d, block_c):
        from repro.core.calibration import CalibrationModel
        from repro.core.decomposition import RecordScalars
        from repro.core.estimator import refine_level
        from repro.core.packing import unpack_ternary

        packed, q, d0, delta_sq, cross, norm, rho, w, bias = _setup_refine(
            c, d, seed=c * d)
        out = refine_scores(packed, q, d0, delta_sq, cross, norm, rho, w,
                            bias, block_c=block_c)
        model = CalibrationModel(w=w, bias=bias, resid_std=jnp.asarray(0.0))
        scalars = RecordScalars(delta_sq=delta_sq, cross=cross, rho=rho,
                                norm=norm)
        state = refine_level(q, d0, scalars, unpack_ternary(packed, d),
                             model, k=10)
        assert out.shape == (c, 3)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(state.est), rtol=2e-5,
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(out[:, 1] - out[:, 2]),
                                   np.asarray(state.lo), rtol=2e-5,
                                   atol=2e-5)


class TestBatchedRefineKernel:
    @pytest.mark.parametrize("nq,c,d,block_c", [(3, 130, 63, 64),
                                                (5, 512, 100, 256),
                                                (1, 7, 11, 64)])
    def test_matches_per_query_kernel(self, nq, c, d, block_c):
        from repro.kernels.ops import refine_scores_batch

        per_query = []
        packed_b, d0_b, dsq_b, cross_b, norm_b, rho_b, q_b = \
            [], [], [], [], [], [], []
        for i in range(nq):
            packed, q, d0, delta_sq, cross, norm, rho, w, bias = \
                _setup_refine(c, d, seed=100 + i)
            per_query.append(refine_scores(packed, q, d0, delta_sq, cross,
                                           norm, rho, w, bias,
                                           block_c=block_c))
            packed_b.append(packed); q_b.append(q); d0_b.append(d0)
            dsq_b.append(delta_sq); cross_b.append(cross)
            norm_b.append(norm); rho_b.append(rho)
        out = refine_scores_batch(jnp.stack(packed_b), jnp.stack(q_b),
                                  jnp.stack(d0_b), jnp.stack(dsq_b),
                                  jnp.stack(cross_b), jnp.stack(norm_b),
                                  jnp.stack(rho_b), w, bias,
                                  block_c=block_c)
        assert out.shape == (nq, c, 3)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.stack(per_query)),
                                   rtol=2e-5, atol=2e-5)


class TestADCKernel:
    @pytest.mark.parametrize("c,m,k", [(64, 8, 32), (128, 16, 256),
                                       (500, 32, 64), (13, 4, 16),
                                       (256, 96, 256)])
    def test_matches_ref(self, c, m, k):
        key = jax.random.PRNGKey(c + m + k)
        codes = jax.random.randint(key, (c, m), 0, k).astype(jnp.uint8)
        lut = jax.random.uniform(jax.random.fold_in(key, 1), (m, k))
        out = adc_scores(codes, lut)
        expect = ref.pq_adc_ref(codes, lut)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)

    def test_matches_pq_module(self):
        from repro.quant import pq
        from repro.data import make_embeddings
        x = make_embeddings(jax.random.PRNGKey(0), 1000, 64, clusters=8)
        cb = pq.train(jax.random.PRNGKey(1), x, m=8, k=64, iters=5)
        codes = pq.encode(cb, x[:300])
        q = x[500]
        lut = pq.adc_table(cb, q)
        out = adc_scores(codes, lut)
        expect = pq.adc_distances(lut, codes)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-5)

    @given(st.integers(1, 300), st.sampled_from([2, 4, 8, 16]),
           st.sampled_from([16, 64, 256]), st.integers(0, 99))
    @settings(max_examples=10, deadline=None)
    def test_property(self, c, m, k, seed):
        key = jax.random.PRNGKey(seed)
        codes = jax.random.randint(key, (c, m), 0, k).astype(jnp.uint8)
        lut = jax.random.normal(jax.random.fold_in(key, 1), (m, k))
        np.testing.assert_allclose(np.asarray(adc_scores(codes, lut)),
                                   np.asarray(ref.pq_adc_ref(codes, lut)),
                                   rtol=2e-5, atol=2e-5)
