"""Staged search executor tests: backend parity, pluggable front stages,
micro-batching, and the device-counter → QueryCost flow."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import (PipelineConfig, build, make_executor, recall_at_k,
                        search)
from repro.anns.executor import SearchExecutor
from repro.anns.stages import (GraphFrontStage, IVFFrontStage,
                               PallasRefineBackend, ReferenceRefineBackend)
from repro.data import make_dataset
from repro.serving import Retriever


@pytest.fixture(scope="module")
def ds():
    return make_dataset(jax.random.PRNGKey(0), n=8000, d=64, n_queries=48,
                        k_gt=100, clusters=32)


@pytest.fixture(scope="module")
def index(ds):
    cfg = PipelineConfig(dim=64, pq_m=8, pq_k=64, nlist=32, nprobe=8,
                         final_k=10, refine_budget=40)
    return build(jax.random.PRNGKey(1), ds.x, cfg)


def _ledger_dict(cost):
    return {k: (t.accesses, t.bytes) for k, t in cost.ledger.items()}


class TestBackendParity:
    def test_identical_topk_ids(self, ds, index):
        # Acceptance: search() produces identical top-k ids under both
        # refinement backends on a fixed-seed synthetic dataset.
        pred_ref, cost_ref = search(index, ds.queries, k=10,
                                    backend="reference")
        pred_pal, cost_pal = search(index, ds.queries, k=10,
                                    backend="pallas")
        assert jnp.array_equal(pred_ref, pred_pal)
        assert _ledger_dict(cost_ref) == _ledger_dict(cost_pal)

    def test_identical_under_quantile_bound(self, ds):
        cfg = PipelineConfig(dim=64, pq_m=8, pq_k=64, nlist=32, nprobe=8,
                             final_k=10, refine_budget=40, bound="quantile")
        idx = build(jax.random.PRNGKey(3), ds.x, cfg)
        a, _ = search(idx, ds.queries, k=10, backend="reference")
        b, _ = search(idx, ds.queries, k=10, backend="pallas")
        assert jnp.array_equal(a, b)

    def test_identical_with_multilevel_trq(self, ds):
        cfg = PipelineConfig(dim=64, pq_m=8, pq_k=64, nlist=32, nprobe=8,
                             final_k=10, refine_budget=40, trq_levels=2)
        idx = build(jax.random.PRNGKey(4), ds.x, cfg)
        a, cost_a = search(idx, ds.queries, k=10, backend="reference")
        b, cost_b = search(idx, ds.queries, k=10, backend="pallas")
        assert jnp.array_equal(a, b)
        assert _ledger_dict(cost_a) == _ledger_dict(cost_b)


class TestFrontStages:
    def test_graph_front_recall_at_least_ivf(self, ds):
        # At a starved nprobe the IVF front misses boundary neighbors; the
        # graph beam front must make up for it (satellite acceptance:
        # graph recall@10 ≥ IVF recall@10 on the small synthetic dataset).
        cfg = PipelineConfig(dim=64, pq_m=8, pq_k=64, nlist=32, nprobe=1,
                             final_k=10, refine_budget=40)
        idx = build(jax.random.PRNGKey(5), ds.x, cfg)
        pred_ivf, _ = search(idx, ds.queries, k=10, front="ivf")
        rec_ivf = recall_at_k(pred_ivf, ds.gt, 10)
        ex = make_executor(idx, front="graph", beam=192, iters=64, expand=8)
        pred_g, _ = ex.search(ds.queries, k=10)
        rec_g = recall_at_k(pred_g, ds.gt, 10)
        assert rec_g >= rec_ivf

    def test_graph_front_cost_ledger(self, ds, index):
        ex = make_executor(index, front="graph")
        _, cost = ex.search(ds.queries, k=10)
        stages = {k.split(":")[0] for k in cost.ledger}
        assert {"front", "coarse", "handoff", "refine", "rerank"} <= stages

    def test_unknown_front_raises(self, index):
        with pytest.raises(ValueError, match="front"):
            SearchExecutor.from_index(index, front="lsh")

    def test_unknown_backend_raises(self, index):
        with pytest.raises(ValueError, match="backend"):
            SearchExecutor.from_index(index, backend="cuda")


class TestMicroBatching:
    def test_results_and_ledger_invariant(self, ds, index):
        full = make_executor(index)
        micro = make_executor(index, micro_batch=7)   # does not divide 48
        a, cost_a = full.search(ds.queries, k=10)
        b, cost_b = micro.search(ds.queries, k=10)
        assert jnp.array_equal(a, b)
        assert _ledger_dict(cost_a) == _ledger_dict(cost_b)

    def test_serving_retriever(self, ds, index):
        r = Retriever(index=index, micro_batch=8)
        ids, cost = r.retrieve(ds.queries[:16], k=5)
        assert ids.shape == (16, 5)
        assert cost.total_seconds() > 0
        r.retrieve(ds.queries[:16], k=5)
        # running ledger accumulates across calls
        assert r.total_cost.ledger["rerank:ssd"].accesses == \
            2 * cost.ledger["rerank:ssd"].accesses


class TestMultiLevelTraffic:
    def test_deeper_levels_charged_actual_survivors(self, ds):
        # Level ℓ ≥ 1 codes stream only for survivors of level ℓ−1, so the
        # ledger must charge the per-level entering counts emitted by the
        # backends (refine_alive_l{ℓ}) — NOT the final survivor count,
        # which under-charges every intermediate level (the alive chain
        # only shrinks).
        cfg = PipelineConfig(dim=64, pq_m=8, pq_k=64, nlist=32, nprobe=8,
                             final_k=10, refine_budget=40, trq_levels=3)
        idx = build(jax.random.PRNGKey(6), ds.x, cfg)
        ex = make_executor(idx)
        cand = ex.front.candidates(ds.queries)
        refined = ex.backend.refine(ds.queries, cand, idx.trq, k=10,
                                    bound=cfg.bound, z=cfg.z)
        n_l1 = int(refined.counters["refine_alive_l1"])
        n_l2 = int(refined.counters["refine_alive_l2"])
        n_final = int(refined.counters["refine_alive"])
        assert n_l1 >= n_l2 >= n_final          # monotone pruning chain
        _, cost = ex.search(ds.queries, k=10)
        n_cand = cost.ledger["coarse:hbm"].accesses
        assert cost.ledger["refine:cxl"].accesses == n_cand + n_l1 + n_l2
        # bytes bill at the tier's min transfer grain when records are small
        from repro.memory import Tier
        per_access = max(idx.layout.far_bytes,
                         cost.model[Tier.CXL].min_grain_B)
        assert cost.ledger["refine:cxl"].bytes == \
            (n_cand + n_l1 + n_l2) * per_access


class TestCostFlow:
    def test_counters_are_device_side(self, ds, index):
        cand = make_executor(index).front.candidates(ds.queries[:4])
        assert all(isinstance(v, jax.Array) for v in cand.counters.values())

    def test_facade_matches_executor(self, ds, index):
        a, cost_a = search(index, ds.queries, k=10)
        b, cost_b = make_executor(index).search(ds.queries, k=10)
        assert jnp.array_equal(a, b)
        assert _ledger_dict(cost_a) == _ledger_dict(cost_b)

    def test_executor_matches_legacy_ledger_shape(self, ds, index):
        _, cost = search(index, ds.queries, k=10)
        stages = {k.split(":")[0] for k in cost.ledger}
        assert stages == {"coarse", "handoff", "refine", "rerank"}
        # stage ordering of traffic magnitudes: every candidate streams
        # level-0 codes; only ≤ budget·Q survivors hit SSD
        assert cost.ledger["refine:cxl"].accesses == \
            cost.ledger["coarse:hbm"].accesses
        assert cost.ledger["rerank:ssd"].accesses <= 40 * ds.queries.shape[0]


class TestGraphPrimitives:
    """index/graph.py building blocks: the vectorized build against a
    per-edge reference loop, the per-degree graph cache, and the online
    maintenance ops (insert_nodes / compact_graph) the streaming layer
    relies on."""

    @staticmethod
    def _build_reference(x, degree):
        """graph.build's algorithm with per-edge Python loops: same kNN
        pruning, same (source, rank) reverse-edge acceptance order, same
        forward-edge padding and shortcut rng — the spec the vectorized
        scatter must reproduce bit for bit."""
        from repro.data.synthetic import brute_force_topk

        n = x.shape[0]
        fwd = int(degree * 3 / 4)
        knn = np.asarray(brute_force_topk(x, x, degree + 1))
        mask = knn != np.arange(n)[:, None]
        order = np.argsort(~mask, axis=1, kind="stable")
        pruned = np.take_along_axis(knn, order, axis=1)[:, :degree]
        neighbors = np.full((n, degree), -1, np.int32)
        neighbors[:, :fwd] = pruned[:, :fwd]
        fill = np.full(n, fwd)
        for i in range(n):                      # reverse edges, edge order
            for j in pruned[i, :fwd]:
                if fill[j] < degree:
                    neighbors[j, fill[j]] = i
                    fill[j] += 1
        for i in range(n):                      # pad with forward edges
            for c in range(fill[i], degree):
                neighbors[i, c] = pruned[i, min(fwd + c - fill[i],
                                                degree - 1)]
        rng = np.random.default_rng(7)
        neighbors[:, degree - 2:] = rng.integers(0, n, size=(n, 2))
        return neighbors.astype(np.int32)

    def test_vectorized_build_matches_reference_loop(self):
        from repro.index import graph as graph_mod

        x = jax.random.normal(jax.random.PRNGKey(11), (400, 16))
        got = np.asarray(graph_mod.build(x, degree=8).neighbors)
        want = self._build_reference(x, 8)
        np.testing.assert_array_equal(got, want)

    def test_graph_for_caches_per_degree(self, index):
        from repro.anns.stages import graph_for

        g16 = graph_for(index)
        assert graph_for(index) is g16             # cache hit
        g8 = graph_for(index, degree=8)
        assert g8 is not g16                       # degree keys the cache
        assert g8.neighbors.shape == (index.x.shape[0], 8)
        assert graph_for(index, degree=8) is g8
        assert graph_for(index, degree=16) is g16  # earlier entry survives

    def test_insert_nodes_invariants(self, ds):
        from repro.index import graph as graph_mod

        x = np.asarray(ds.x[:500], np.float32)
        n_old, n = 460, 500
        g0 = np.asarray(graph_mod.build(x[:n_old], degree=8).neighbors)
        g1 = graph_mod.insert_nodes(g0, x, n_old)
        assert g1.shape == (n, 8) and g1.dtype == np.int32
        assert (g1 >= 0).all() and (g1 < n).all()
        # new rows were wired against the PRE-batch graph: their forward
        # edges can only point at pre-existing rows
        assert (g1[n_old:] < n_old).all()
        # pre-batch rows change only by reverse-edge replacement, and a
        # replaced slot always points at an inserted row
        changed = g1[:n_old] != g0
        assert (g1[:n_old][changed] >= n_old).all()
        # deterministic: same inputs, same adjacency
        np.testing.assert_array_equal(g1, graph_mod.insert_nodes(g0, x,
                                                                 n_old))

    def test_insert_single_node_gets_reverse_edge(self, ds):
        from repro.index import graph as graph_mod

        x = np.asarray(ds.x[:301], np.float32)
        g0 = np.asarray(graph_mod.build(x[:300], degree=8).neighbors)
        g1 = graph_mod.insert_nodes(g0, x, 300)
        # the j==0 reverse edge is unconditional, so a freshly inserted
        # node is immediately reachable from its nearest beam hit
        assert (g1[:300] == 300).any()

    def test_insert_nodes_rejects_wrong_n_old(self, ds):
        from repro.index import graph as graph_mod

        x = np.asarray(ds.x[:300], np.float32)
        g0 = np.asarray(graph_mod.build(x[:290], degree=8).neighbors)
        with pytest.raises(ValueError, match="n_old"):
            graph_mod.insert_nodes(g0, x, 280)

    def test_compact_graph_invariants(self, ds):
        from repro.index import graph as graph_mod

        x = np.asarray(ds.x[:400], np.float32)
        g = np.asarray(graph_mod.build(x, degree=8).neighbors)
        dead = np.arange(50, 130)
        live = np.setdiff1d(np.arange(400), dead)
        out = graph_mod.compact_graph(g, x, live)
        assert out.shape == (live.size, 8) and out.dtype == np.int32
        # no dangling edges: everything points at a live, renumbered row
        assert (out >= 0).all() and (out < live.size).all()
        # rows whose edges were all live are a pure renumbering
        new_of = np.full(400, -1, np.int32)
        new_of[live] = np.arange(live.size, dtype=np.int32)
        direct = new_of[g[live]]
        untouched = (direct >= 0).all(axis=1)
        assert untouched.any()
        np.testing.assert_array_equal(out[untouched], direct[untouched])

    def test_compact_graph_rejects_empty(self, ds):
        from repro.index import graph as graph_mod

        x = np.asarray(ds.x[:50], np.float32)
        g = np.asarray(graph_mod.build(x, degree=8).neighbors)
        with pytest.raises(ValueError, match="zero live rows"):
            graph_mod.compact_graph(g, x, np.array([], np.int64))
