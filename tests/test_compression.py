"""Gradient-compression tests: error-feedback telescoping + multi-device
compressed psum (subprocess with 8 fake devices)."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.compression import (compress_grads, compress_leaf,
                                     dequantize_int8, quantize_int8,
                                     wire_bytes)


class TestQuantize:
    def test_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s) - x))
        assert err.max() <= float(s) / 2 + 1e-6

    def test_error_feedback_telescopes(self):
        # Σ sent_t must converge to Σ g_t (compression noise cancels).
        key = jax.random.PRNGKey(1)
        err = jnp.zeros((256,))
        total_sent = jnp.zeros((256,))
        total_true = jnp.zeros((256,))
        for t in range(50):
            g = jax.random.normal(jax.random.fold_in(key, t), (256,))
            sent, err = compress_leaf(g, err)
            total_sent += sent
            total_true += g
        resid = float(jnp.max(jnp.abs(total_sent - total_true)))
        one_step = float(jnp.max(jnp.abs(
            compress_leaf(jax.random.normal(key, (256,)),
                          jnp.zeros((256,)))[0])))
        # after 50 steps the residual stays at single-quantization scale,
        # not 50× it — the defining error-feedback property
        assert resid < 0.2 * one_step * 50

    def test_tree_api_and_wire_bytes(self):
        grads = {"a": jnp.ones((64, 64)), "b": jnp.ones((128,))}
        sent, err = compress_grads(grads, None)
        assert jax.tree.structure(sent) == jax.tree.structure(grads)
        assert wire_bytes(grads, compressed=True) * 3.9 < \
            wire_bytes(grads, compressed=False)


def test_compressed_psum_multidevice():
    """Run the shard_map int8 psum on 8 fake devices in a subprocess."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.train.compression import compressed_psum

mesh = jax.make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
err0 = jnp.zeros((8, 64))

from repro.compat import shard_map

@partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
         out_specs=(P("data"), P("data")))
def f(xs, es):
    tot, err = compressed_psum(xs[0], "data", es[0])
    return tot[None], err[None]

tot, err = f(x, err0)
true = jnp.sum(x, axis=0)
rel = float(jnp.max(jnp.abs(tot[0] - true)) / jnp.max(jnp.abs(true)))
assert rel < 0.05, rel
# all replicas agree
np.testing.assert_allclose(np.asarray(tot[0]), np.asarray(tot[7]), rtol=1e-6)
print("OK rel=%.4f" % rel)
"""
    try:
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True,
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                           cwd="/root/repo", timeout=600)
    except subprocess.TimeoutExpired:
        # NB: this can also mask a deadlocked collective; on CI-class
        # machines the run takes well under the limit, so a skip there
        # means the host, not the code, should be investigated.
        pytest.skip("8-fake-device subprocess exceeded 600s on this host "
                    "(cold jax start under load) — environment, not code")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
