"""Training-infrastructure tests: optimizer, checkpoint/restore (incl.
elastic resharding semantics), fault-tolerant loop behaviours."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data import make_token_batch
from repro.models import build_model, loss_fn
from repro.train import checkpoint as ckpt
from repro.train import optimizer
from repro.train.loop import TrainConfig, train


@pytest.fixture()
def api():
    return build_model(ARCHS["qwen2.5-3b"].reduced())


class TestOptimizer:
    def test_loss_decreases(self, api):
        # Fresh uniform-random tokens every step sit AT the entropy floor
        # (loss ≈ ln vocab from init), so train on one fixed batch via the
        # extra_batch hook: memorization must drive the loss down.
        tc = TrainConfig(steps=30, batch=4, seq_len=32, lr=1e-3,
                         ckpt_every=0, ckpt_dir="/tmp/ck_never")
        fixed = make_token_batch(jax.random.PRNGKey(42), 4, 32,
                                 api.cfg.vocab)
        state = train(api, tc, resume=False, extra_batch=lambda k: fixed)
        first = np.mean(state.losses[:5])
        last = np.mean(state.losses[-5:])
        assert last < first, (first, last)

    def test_grad_clip(self):
        params = {"w": jnp.ones((4,))}
        opt = optimizer.init(params)
        grads = {"w": jnp.full((4,), 1e6)}
        new_params, _ = optimizer.update(grads, opt, params, lr=0.1,
                                         grad_clip=1.0, weight_decay=0.0)
        # update magnitude bounded by lr (clipped unit-norm grad)
        assert float(jnp.max(jnp.abs(new_params["w"] - params["w"]))) < 0.2


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, api):
        params = api.init(jax.random.PRNGKey(0))
        opt = optimizer.init(params)
        tree = {"params": params, "opt": opt}
        ckpt.save(str(tmp_path), 7, tree)
        assert ckpt.latest_step(str(tmp_path)) == 7
        restored = ckpt.restore(str(tmp_path), 7, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_overwrite_and_latest(self, tmp_path):
        tree = {"x": jnp.arange(4.0)}
        ckpt.save(str(tmp_path), 1, tree)
        ckpt.save(str(tmp_path), 2, {"x": jnp.arange(4.0) * 2})
        assert ckpt.latest_step(str(tmp_path)) == 2
        r = ckpt.restore(str(tmp_path), 2, tree)
        np.testing.assert_allclose(np.asarray(r["x"]),
                                   np.arange(4.0) * 2)

    def test_elastic_restore_new_sharding(self, tmp_path):
        # restore onto a different device layout (1-dev mesh here, but the
        # API path — device_put with explicit shardings — is the same)
        from jax.sharding import NamedSharding, PartitionSpec as P
        tree = {"x": jnp.arange(16.0).reshape(4, 4)}
        ckpt.save(str(tmp_path), 3, tree)
        mesh = jax.make_mesh((1,), ("data",))
        sh = {"x": NamedSharding(mesh, P("data", None))}
        r = ckpt.restore(str(tmp_path), 3, tree, shardings=sh)
        assert r["x"].sharding.spec == P("data", None)


class TestFaultTolerance:
    def test_resume_from_checkpoint(self, tmp_path, api):
        tc = TrainConfig(steps=10, batch=2, seq_len=16, ckpt_every=5,
                         ckpt_dir=str(tmp_path))
        s1 = train(api, tc, resume=False)
        assert ckpt.latest_step(str(tmp_path)) == 10
        # "crash" and resume: should be a no-op (already at step 10)
        s2 = train(api, tc, resume=True)
        assert s2.step == 10 and len(s2.losses) == 0
        # extend the run — resumes from 10, trains 5 more
        tc2 = TrainConfig(steps=15, batch=2, seq_len=16, ckpt_every=5,
                          ckpt_dir=str(tmp_path))
        s3 = train(api, tc2, resume=True)
        assert s3.step == 15 and len(s3.losses) == 5

    def test_deterministic_replay(self, api, tmp_path):
        tc = TrainConfig(steps=6, batch=2, seq_len=16, ckpt_every=0,
                         ckpt_dir=str(tmp_path), seed=42)
        a = train(api, tc, resume=False)
        b = train(api, tc, resume=False)
        np.testing.assert_allclose(a.losses, b.losses, rtol=1e-5)

    def test_straggler_detection(self, api, tmp_path):
        import time
        events = []
        slow = {"n": 0}

        def spy(step, dt):
            events.append(step)

        orig = jax.block_until_ready
        tc = TrainConfig(steps=8, batch=2, seq_len=16, ckpt_every=0,
                         ckpt_dir=str(tmp_path), straggler_factor=2.0)

        def extra(key):
            slow["n"] += 1
            if slow["n"] == 6:
                time.sleep(1.0)        # inject a straggling step
            return {}

        state = train(api, tc, resume=False, on_straggler=spy,
                      extra_batch=extra)
        assert state.stragglers >= 1 and len(events) >= 1
