"""Adaptive hot/cold tiered placement (``memory.placement`` +
``anns.tiered``).

Pins the three contracts the tiered layout makes:

1. **All-warm identity** — a ``TieredIndex`` that has never rebalanced is
   bit-identical to the wrapped static index on every front × backend:
   same ids, same distances, same per-entry ledger bytes.
2. **Policy pays off under skew** — replaying a seeded Zipfian trace,
   rebalancing drops the modeled ``total_seconds()`` versus the all-warm
   placement without losing recall.
3. **Migration invalidates** — ``rebalance_tiers()`` bumps the generation
   so both the executor cache and the serving result cache drop stale
   entries (on both refine backends).
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.anns import (Database, PipelineConfig, PlanError, QueryPlan,
                        TieredConfig, TieredIndex, build, make_executor,
                        recall_at_k, registry)
from repro.data.synthetic import brute_force_topk
from repro.memory import (TIER_COLD, TIER_HOT, TIER_WARM, HeatTracker,
                          QueryCost, Tier, occupancy, plan_migration,
                          plan_placement)
from repro.serving import ResultCache, query_key


# ---------------------------------------------------------------------------
# policy unit tests (pure numpy, no device)

def test_tiered_config_validation():
    with pytest.raises(ValueError, match="decay"):
        TieredConfig(decay=1.0)
    with pytest.raises(ValueError, match=">= 0"):
        TieredConfig(hot_rows_frac=-0.1)
    with pytest.raises(ValueError, match="<= 1"):
        TieredConfig(hot_rows_frac=0.7, cold_rows_frac=0.7)


def test_heat_tracker_ema_is_deterministic():
    ht = HeatTracker(4, decay=0.5)
    ht.observe([8, 0, 4, 0])
    np.testing.assert_allclose(ht.heat, [4.0, 0.0, 2.0, 0.0])
    ht.observe([0, 8, 4, 0])
    np.testing.assert_allclose(ht.heat, [2.0, 4.0, 3.0, 0.0])
    assert ht.observations == 2
    ht.reset()
    assert ht.observations == 0 and not ht.heat.any()
    with pytest.raises(ValueError, match="shape"):
        ht.observe(np.zeros(5))


def test_plan_placement_budgets_and_ties():
    rows = np.full(4, 10)
    # ties broken by list id asc; hot budget 0.5*40=20 rows → lists 0, 1
    tiers = plan_placement([5.0, 5.0, 1.0, 0.0], rows,
                           TieredConfig(hot_rows_frac=0.5,
                                        cold_rows_frac=0.25))
    assert tiers.tolist() == [TIER_HOT, TIER_HOT, TIER_WARM, TIER_COLD]
    assert tiers.dtype == np.int8


def test_plan_placement_never_promotes_unobserved():
    tiers = plan_placement(np.zeros(4), np.full(4, 10),
                           TieredConfig(hot_rows_frac=1.0))
    assert (tiers == TIER_WARM).all()


def test_plan_placement_disabled_is_all_warm():
    tiers = plan_placement([9.0, 1.0], [10, 10],
                           TieredConfig(hot_rows_frac=1.0,
                                        cold_rows_frac=0.0, enabled=False))
    assert (tiers == TIER_WARM).all()


def test_plan_migration_and_occupancy():
    rows = np.full(4, 10)
    old = np.full(4, TIER_WARM, np.int8)
    new = np.array([TIER_HOT, TIER_HOT, TIER_WARM, TIER_COLD], np.int8)
    assert plan_migration(old, new, rows) == {("warm", "hot"): 20,
                                              ("warm", "cold"): 10}
    assert plan_migration(new, new, rows) == {}
    assert occupancy(new, rows) == {"hot": (2, 20), "warm": (1, 10),
                                    "cold": (1, 10)}


def test_query_cost_by_tier_pools_stage_keys():
    cost = QueryCost()
    cost.record("refine", Tier.CXL, 10, 8)
    cost.record("delta", Tier.CXL, 5, 8)
    cost.record("hot", Tier.HBM, 3, 128)
    by = cost.by_tier()
    assert by[Tier.CXL].accesses == 15
    assert by[Tier.CXL].bytes == 15 * 64          # CXL min_grain 64B
    assert by[Tier.HBM].accesses == 3
    assert by[Tier.SSD].accesses == 0             # untouched tiers present
    assert set(by) == set(Tier)


# ---------------------------------------------------------------------------
# end-to-end fixtures

@pytest.fixture(scope="module")
def ds():
    from repro.data import make_dataset
    return make_dataset(jax.random.PRNGKey(0), n=1500, d=32, n_queries=6,
                        k_gt=20, clusters=8)


@pytest.fixture(scope="module")
def index(ds):
    cfg = PipelineConfig(dim=32, pq_m=4, pq_k=32, nlist=16, nprobe=4,
                         final_k=5, refine_budget=20, trq_levels=2)
    return build(jax.random.PRNGKey(1), ds.x, cfg)


@pytest.fixture(scope="module")
def skewed_queries(ds):
    """Seeded Zipfian trace: anchor rows ranked by distance to one point,
    query popularity ∝ rank^-1.3 — a handful of IVF lists absorb almost
    all probes, the regime adaptive placement is built for."""
    x = np.asarray(ds.x)
    near = np.argsort(((x - x[0]) ** 2).sum(axis=1))
    rng = np.random.default_rng(11)
    p = 1.0 / np.arange(1, len(near) + 1, dtype=np.float64) ** 1.3
    rows = near[rng.choice(len(near), size=48, p=p / p.sum())]
    q = x[rows] + 0.02 * rng.standard_normal((48, x.shape[1]))
    q = (q / np.linalg.norm(q, axis=1, keepdims=True)).astype(np.float32)
    return jnp.asarray(q)


def _ledger_dict(cost):
    return {k: (t.accesses, t.bytes) for k, t in cost.ledger.items()}


# ---------------------------------------------------------------------------
# contract 1: all-warm ≡ static, bit for bit, across the matrix

@pytest.mark.parametrize("front,backend",
                         list(itertools.product(registry.front_names(),
                                                registry.backend_names())))
def test_all_warm_matches_static_bitwise(ds, index, front, backend):
    ti = TieredIndex(index)                       # never rebalanced
    assert (ti.list_tier == TIER_WARM).all() and ti.generation == 0
    plan = QueryPlan(front=front, backend=backend, k=5)
    a = Database.wrap(index).query(ds.queries, plan=plan)
    b = Database.wrap(ti).query(ds.queries, plan=plan)
    assert jnp.array_equal(a.ids, b.ids)
    assert jnp.array_equal(a.distances, b.distances)
    assert _ledger_dict(a.cost) == _ledger_dict(b.cost)


# ---------------------------------------------------------------------------
# contract 2: Zipfian trace → cost drops, recall does not

def test_policy_beats_all_warm_under_zipfian_skew(ds, index, skewed_queries):
    ti = TieredIndex(index, TieredConfig(decay=0.5, hot_rows_frac=0.25,
                                         cold_rows_frac=0.2))
    db = Database.wrap(ti)
    plan = QueryPlan(front="ivf", k=5)
    warm = db.query(skewed_queries, plan=plan)    # builds heat as it runs
    out = ti.rebalance_tiers()
    assert out["changed"] and out["occupancy"]["hot"][0] > 0
    hot = db.query(skewed_queries, plan=plan)

    gt = brute_force_topk(ds.x, skewed_queries, 20)
    r_warm = recall_at_k(warm.ids, gt, 5)
    r_hot = recall_at_k(hot.ids, gt, 5)
    assert r_hot >= r_warm                        # exact HBM scoring ≥ TRQ
    assert "hot:hbm" in hot.cost.ledger
    assert hot.cost.total_seconds() < warm.cost.total_seconds()


def test_rebalance_gated_by_min_observations(ds, index):
    ti = TieredIndex(index, TieredConfig(hot_rows_frac=0.25,
                                         min_observations=99))
    Database.wrap(ti).query(ds.queries, plan=QueryPlan(front="ivf", k=5))
    out = ti.rebalance_tiers()
    assert not out["changed"] and ti.generation == 0
    out = ti.rebalance_tiers(force=True)          # explicit override
    assert out["changed"] and ti.generation == 1


# ---------------------------------------------------------------------------
# contract 3: migration invalidates executor + result caches (both backends)

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_rebalance_invalidates_executor_cache(ds, index, backend):
    ti = TieredIndex(index, TieredConfig(hot_rows_frac=0.25,
                                         cold_rows_frac=0.25))
    ex0 = make_executor(ti, front="ivf", backend=backend, layout="tiered")
    assert make_executor(ti, front="ivf", backend=backend,
                         layout="tiered") is ex0          # memoized
    Database.wrap(ti).query(ds.queries,
                            plan=QueryPlan(front="ivf", backend=backend, k=5))
    assert ti.rebalance_tiers()["changed"]
    ex1 = make_executor(ti, front="ivf", backend=backend, layout="tiered")
    assert ex1 is not ex0
    # stale-generation entries are pruned, not retained forever
    assert all(k[0] == ti.generation for k in ti._executor_cache)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_rebalance_invalidates_result_cache(ds, index, backend):
    ti = TieredIndex(index, TieredConfig(hot_rows_frac=0.25,
                                         cold_rows_frac=0.25))
    db = Database.wrap(ti)
    plan = db.validate(QueryPlan(front="ivf", backend=backend, k=5))
    res = db.query(ds.queries, plan=plan)
    rc = ResultCache()
    rc.attach(ti)                                 # generation hook
    qk = query_key(ds.queries[0])
    rc.insert(qk, plan, ti.generation, np.asarray(res.ids[0]),
              np.asarray(res.distances[0]))
    assert rc.lookup(qk, plan, ti.generation) is not None
    assert ti.rebalance_tiers()["changed"]
    assert rc.lookup(qk, plan, ti.generation) is None
    assert rc.stats.invalidations == 1


def test_rebalance_noop_keeps_generation(ds, index):
    ti = TieredIndex(index, TieredConfig(hot_rows_frac=0.25))
    Database.wrap(ti).query(ds.queries, plan=QueryPlan(front="ivf", k=5))
    assert ti.rebalance_tiers()["changed"]
    gen = ti.generation
    out = ti.rebalance_tiers()                    # same heat → same placement
    assert not out["changed"] and ti.generation == gen


# ---------------------------------------------------------------------------
# plan-time errors

def test_tiered_rejects_shards_with_guidance(ds, index):
    db = Database.wrap(TieredIndex(index))
    with pytest.raises(PlanError, match="tiered.*per-device"):
        db.validate(QueryPlan(front="ivf", shards=2, k=5))


def test_tiered_rejects_baseline_mode(ds, index):
    db = Database.wrap(TieredIndex(index))
    with pytest.raises(PlanError, match="baseline"):
        db.validate(QueryPlan(front="ivf", mode="baseline", k=5))


def test_pair_error_names_tiered_alternatives():
    msg = str(registry._pair_error("front", "flat", ("static",), "tiered"))
    # the error must steer the caller to what DOES run on tiered
    assert "'tiered'" in msg and "ivf" in msg and "graph" in msg
    assert "[static]" in msg


def test_hot_path_excludes_hot_rows_from_ssd_rerank(ds, index,
                                                    skewed_queries):
    """Hot candidates are scored from HBM: the SSD rerank ledger must
    shrink by exactly the fetches that went hot, not just get relabeled."""
    ti = TieredIndex(index, TieredConfig(decay=0.5, hot_rows_frac=0.25))
    db = Database.wrap(ti)
    plan = QueryPlan(front="ivf", k=5)
    warm = db.query(skewed_queries, plan=plan)
    assert ti.rebalance_tiers()["changed"]
    hot = db.query(skewed_queries, plan=plan)
    assert hot.cost.ledger["rerank:ssd"].accesses \
        < warm.cost.ledger["rerank:ssd"].accesses
    by = hot.cost.by_tier()
    assert by[Tier.HBM].accesses > warm.cost.by_tier()[Tier.HBM].accesses
