"""§V-C storage efficiency + §V-E build overhead + Fig. 2 breakdown.

768-D: FaTRQ = 768/5 + 8 = 162 B vs 4-bit SQ = 384(+8) B → 2.4×.
Build: single parallel pass per vector (ternary encode is O(D log D)).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import dataset, emit, time_call
from repro.core import encode_database, pack_ternary, storage_bytes, \
    ternary_encode
from repro.memory import QueryCost, RecordLayout, Tier
from repro.quant import pq as pq_mod
from repro.quant import sq as sq_mod


def run() -> None:
    # --- storage table (§V-C)
    fatrq_b = storage_bytes(768)
    sq4_b = sq_mod.sq_bytes_per_record(768, 4)
    sq3_b = sq_mod.sq_bytes_per_record(768, 3)
    emit("storage_fatrq_768d_bytes", 0.0, f"bytes={fatrq_b}")
    emit("storage_sq4_768d_bytes", 0.0,
         f"bytes={sq4_b};fatrq_saving={sq4_b / fatrq_b:.2f}x")
    emit("storage_sq3_768d_bytes", 0.0, f"bytes={sq3_b}")
    emit("storage_fatrq_bits_per_dim", 0.0, "bits=1.667;entropy_bound=1.585")

    # --- offline build cost (§V-E): one parallel pass per vector
    ds = dataset(8000, 768, 32)
    enc = jax.jit(lambda xx: pack_ternary(ternary_encode(xx).code))
    us = time_call(enc, ds.x, iters=3)
    emit("build_ternary_encode_us_per_8k_vectors", us,
         f"vectors_per_sec={8000 / (us * 1e-6):.0f}")

    # --- Fig. 2 runtime breakdown of the BASELINE pipeline (tier model):
    # traversal (HBM) vs refinement (SSD) share of query time.
    lay = RecordLayout(dim=768, pq_m=96)
    cost = QueryCost()
    cands = 320                        # IVF @90% recall (paper, Wiki)
    cost.record("traversal", Tier.HBM, cands * 40, lay.fast_bytes)  # probes
    cost.record("rerank", Tier.SSD, cands, lay.ssd_bytes)
    br = cost.breakdown()
    total = sum(br.values())
    emit("fig2_refinement_share", 0.0,
         f"ssd_pct={100 * br['ssd'] / total:.1f};"
         f"traversal_pct={100 * br['hbm'] / total:.1f}")


if __name__ == "__main__":
    run()
