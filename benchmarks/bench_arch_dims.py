"""FaTRQ storage/traffic across the 10 assigned backbones' embedding
dims (DESIGN.md §4): the retriever is architecture-agnostic — this table
shows the far-memory record size and SSD-byte saving at each arch's
hidden size (what a RAG deployment of that backbone would store).
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.configs import ARCHS
from repro.core.packing import storage_bytes
from repro.quant import sq as sq_mod


def run() -> None:
    for name in sorted(ARCHS):
        cfg = ARCHS[name]
        d = cfg.d_model
        fatrq = storage_bytes(d)
        sq4 = sq_mod.sq_bytes_per_record(d, 4)
        full = 4 * d
        emit(f"archdim_{name}", 0.0,
             f"d={d};fatrq_B={fatrq};sq4_B={sq4};full_B={full};"
             f"vs_sq4={sq4 / fatrq:.2f}x;vs_full={full / fatrq:.1f}x")


if __name__ == "__main__":
    run()
