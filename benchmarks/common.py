"""Shared benchmark setup: datasets, indexes, timing helpers, CSV output."""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.anns import PipelineConfig, build
from repro.data import make_dataset

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row)


def time_call(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in µs (blocks on jax results)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


@functools.lru_cache(maxsize=4)
def dataset(n: int = 20_000, d: int = 128, nq: int = 64):
    return make_dataset(jax.random.PRNGKey(0), n=n, d=d, n_queries=nq,
                        k_gt=100, clusters=64)


@functools.lru_cache(maxsize=4)
def fatrq_index(n: int = 20_000, d: int = 128, *, budget: int = 40,
                bound: str = "cauchy"):
    ds = dataset(n, d)
    cfg = PipelineConfig(dim=d, pq_m=d // 8, pq_k=256, nlist=64, nprobe=8,
                         final_k=10, refine_budget=budget, bound=bound)
    return ds, build(jax.random.PRNGKey(1), ds.x, cfg)
