"""Shared benchmark setup: datasets, indexes, timing helpers, CSV + JSON
output.

Besides the human-readable ``name,us_per_call,derived`` CSV rows, every
``emit`` also appends a structured record (optionally carrying a QueryCost
breakdown and extra fields like qps/shards); ``write_json`` drains the
records accumulated since the last call into a machine-readable
``BENCH_<bench>.json`` so the perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import datetime
import functools
import json
import subprocess
import time

import jax
import jax.numpy as jnp

from repro.anns import PipelineConfig, QueryPlan, build
from repro.data import make_dataset
from repro.memory import QueryCost

ROWS: list[str] = []
RECORDS: list[dict] = []


@functools.lru_cache(maxsize=1)
def provenance() -> dict:
    """Measurement-environment stamp written into every BENCH record:
    git SHA (``null`` outside a checkout), UTC timestamp, jax version,
    device platform, and device count (fake devices included — the
    sharded benches force host platform devices via XLA_FLAGS)."""
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    return {"git_sha": sha,
            "timestamp_utc": datetime.datetime.now(
                datetime.timezone.utc).isoformat(timespec="seconds"),
            "jax_version": jax.__version__,
            "platform": jax.default_backend(),
            "device_count": jax.device_count()}


def emit(name: str, us_per_call: float, derived: str = "",
         cost: QueryCost | None = None, plan: QueryPlan | None = None,
         **fields) -> None:
    """One CSV row + one structured record.

    ``plan`` is the resolved ``QueryPlan`` the measurement ran under; it is
    written into EVERY record (``None`` for rows that are not a planned
    search, e.g. kernel micro-benchmarks) so perf points in the
    ``BENCH_*.json`` trajectory are attributable to an exact plan.  Every
    record also carries the ``provenance()`` stamp so trajectory points
    are attributable to a commit + measurement environment.
    """
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row)
    rec: dict = {"name": name, "us_per_call": us_per_call}
    if derived:
        rec["derived"] = derived
    if cost is not None:
        rec["cost_breakdown_s"] = cost.breakdown()
        rec["cost_total_s"] = cost.total_seconds()
    rec["plan"] = plan.to_record() if plan is not None else None
    rec["provenance"] = provenance()
    rec.update(fields)
    RECORDS.append(rec)


def take_records() -> list[dict]:
    """Drain the structured records accumulated since the last drain."""
    out = list(RECORDS)
    RECORDS.clear()
    return out


def write_json(bench: str, path: str | None = None) -> str:
    """Write the drained records to ``BENCH_<bench>.json`` (or ``path``)."""
    path = path or f"BENCH_{bench}.json"
    payload = {"bench": bench, "records": take_records()}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")
    return path


def time_call(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in µs (blocks on jax results)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


@functools.lru_cache(maxsize=4)
def dataset(n: int = 20_000, d: int = 128, nq: int = 64):
    return make_dataset(jax.random.PRNGKey(0), n=n, d=d, n_queries=nq,
                        k_gt=100, clusters=64)


@functools.lru_cache(maxsize=4)
def fatrq_index(n: int = 20_000, d: int = 128, *, budget: int = 40,
                bound: str = "cauchy"):
    ds = dataset(n, d)
    cfg = PipelineConfig(dim=d, pq_m=d // 8, pq_k=256, nlist=64, nprobe=8,
                         final_k=10, refine_budget=budget, bound=bound)
    return ds, build(jax.random.PRNGKey(1), ds.x, cfg)
