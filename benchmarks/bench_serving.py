"""Serving-engine benchmark: open-loop Poisson arrivals through the
continuous-batching ``ServingEngine`` (serving/scheduler.py) vs the
one-query-at-a-time ``Retriever`` baseline, at matched offered load.

Three configurations per offered rate:

* ``single``  — batching off (max_batch=1): every request dispatches
  alone, the baseline a naive serving frontend gets from ``Retriever``.
* ``batched`` — continuous batching on (coalescer + double-buffered
  front/refine dispatch), result cache off.
* ``batched_cache`` — batching plus the query-result cache; the query
  pool repeats (Zipf-ish head) so a realistic fraction short-circuits.

Latency is virtual-clock microseconds from the Table-I tier model (the
same modeled time every other figure uses): arrivals are a seeded
Poisson process, the scheduler is a discrete-event simulation, so every
record is exactly reproducible.  Emitted per (config, rate):
``serving_{config}_rps{rate}`` with p50 in the us_per_call slot and
p99 / sustained QPS / offered rate / batch stats in the fields.

Standalone: ``python benchmarks/bench_serving.py --devices 8`` fakes 8
host devices (set before jax initializes) and runs the sharded plan;
``--requests N --rates a,b,...`` sizes the trace.  Writes
``BENCH_bench_serving.json``.
"""

from __future__ import annotations

import sys

if __name__ == "__main__":          # must run BEFORE anything imports jax
    import argparse
    import os

    _ap = argparse.ArgumentParser()
    _ap.add_argument("--devices", type=int, default=None,
                     help="fake this many host devices and shard the plan "
                          "across them")
    _ap.add_argument("--requests", type=int, default=96,
                     help="requests per (config, rate) trace")
    _ap.add_argument("--rates", type=str, default="2000,8000",
                     help="comma-separated offered loads (requests/s)")
    _ap.add_argument("--trace", type=str, default=None, metavar="PATH",
                     help="export a Chrome-trace JSON of the LAST "
                          "(config, rate) run to PATH")
    _ap.add_argument("--prom", type=str, default=None, metavar="PATH",
                     help="export the last run's metrics registry in "
                          "Prometheus text format to PATH")
    _CLI_ARGS = _ap.parse_args()
    if _CLI_ARGS.devices and _CLI_ARGS.devices > 1 and \
            "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={_CLI_ARGS.devices}"
        ).strip()
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [os.path.join(_root, "src"), _root]

import jax
import numpy as np

from benchmarks.common import dataset, emit, fatrq_index, write_json
from repro.obs import export, trace as obs_trace
from repro.serving import QueryPlan, Request, ResultCache, ServingEngine

_MAX_BATCH = 8
_POOL = 24          # distinct queries in the arrival mix (repeats → hits)


def _trace(ds, *, n_requests: int, rate_rps: float, seed: int = 0):
    """Seeded open-loop Poisson trace over a repeating query pool."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1e6 / rate_rps, size=n_requests)
    arrivals = np.cumsum(gaps)
    pool = np.asarray(ds.queries[:_POOL])
    picks = rng.integers(0, _POOL, size=n_requests)
    return [Request(query=pool[picks[i]], arrival_us=float(arrivals[i]),
                    rid=i)
            for i in range(n_requests)]


def _run_config(index, ds, *, name: str, rate_rps: float, n_requests: int,
                batching: bool, cache: bool, shards: int | None,
                tracer=None) -> "ServingEngine":
    plan = QueryPlan(shards=shards) if shards and shards > 1 else None
    eng = ServingEngine(
        index, plan=plan, max_batch=_MAX_BATCH, max_wait_us=200.0,
        batching=batching, overlap=batching,  # the baseline is strictly
        # serial: one blocking Retriever call per request, nothing to
        # double-buffer against
        cache=ResultCache(capacity=256) if cache else None,
        tracer=tracer)
    reqs = _trace(ds, n_requests=n_requests, rate_rps=rate_rps)
    resp = eng.run(reqs)
    lat = np.array([r.latency_us for r in resp])
    span_s = (max(r.done_us for r in resp) - reqs[0].arrival_us) / 1e6
    emit(f"serving_{name}_rps{int(rate_rps)}",
         float(np.percentile(lat, 50)),
         f"p99={np.percentile(lat, 99):.0f}us;"
         f"qps={len(resp) / span_s:.0f};batches={eng.stats.batches}",
         cost=eng.total_cost, plan=eng.base_plan,
         p99_us=float(np.percentile(lat, 99)),
         qps_sustained=len(resp) / span_s,
         offered_rps=rate_rps, n_requests=n_requests,
         batches=eng.stats.batches,
         cache_hits=eng.stats.cache_hits,
         padded_slots=eng.stats.padded_slots,
         devices=shards or 1)
    return eng


def run(*, devices: int | None = None, n_requests: int = 96,
        rates=(2000.0, 8000.0), trace_path: str | None = None,
        prom_path: str | None = None) -> None:
    ds, index = fatrq_index()
    avail = len(jax.devices())
    shards = min(devices or 1, avail)
    want_obs = trace_path is not None or prom_path is not None
    eng = tracer = None
    for rate in rates:
        for name, batching, cache in (("single", False, False),
                                      ("batched", True, False),
                                      ("batched_cache", True, True)):
            # only the LAST (config, rate) run is traced — tracing syncs
            # every stage, so earlier (exported-as-BENCH) runs stay on
            # the untraced fast path.  The virtual-clock numbers are
            # identical either way (pinned in tests/test_obs.py).
            last = rate == rates[-1] and name == "batched_cache"
            if want_obs and last:
                tracer = obs_trace.Tracer()
            eng = _run_config(index, ds, name=name, rate_rps=float(rate),
                              n_requests=n_requests, batching=batching,
                              cache=cache, shards=shards,
                              tracer=tracer if last else None)
    if trace_path is not None and tracer is not None:
        export.write_chrome_trace(tracer.spans, trace_path)
        print(f"# wrote {trace_path}")
    if prom_path is not None and eng is not None:
        export.write_prometheus(eng.registry, prom_path)
        print(f"# wrote {prom_path}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(devices=_CLI_ARGS.devices, n_requests=_CLI_ARGS.requests,
        rates=[float(r) for r in _CLI_ARGS.rates.split(",")],
        trace_path=_CLI_ARGS.trace, prom_path=_CLI_ARGS.prom)
    write_json("bench_serving")
