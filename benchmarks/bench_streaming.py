"""Streaming index churn benchmark: insert rate, search latency under
delta/tombstone pressure, and compaction cost.

Three sweeps over a ``StreamingIndex`` wrapping the shared benchmark
database (drift auto-fold disabled so each operating point is measured in
isolation):

* insert-rate — wall-µs per inserted row at growing batch sizes (the
  incremental encode + delta append path);
* search-vs-delta — p50 search wall time and model-time QPS as the delta
  fraction grows (delta candidates stream from far memory on the distinct
  ``delta:cxl`` ledger entry);
* search-vs-tombstones — the same sweep against tombstone fraction (dead
  candidates are masked in the front, so wall time stays flat while
  model-time traffic drops), ending with the ``compact()`` cost and the
  post-compaction search time.

Standalone: ``python benchmarks/bench_streaming.py`` writes
``BENCH_bench_streaming.json``; ``benchmarks/run.py`` includes it in the
full sweep.
"""

from __future__ import annotations

import sys

if __name__ == "__main__":
    import os

    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [os.path.join(_root, "src"), _root]

import time

import jax
import numpy as np

from benchmarks.common import dataset, emit, fatrq_index, time_call, \
    write_json
from repro.anns import Database, StreamingConfig, StreamingIndex
from repro.data import make_embeddings

_K = 10


def _p50_search(st, queries):
    """Planned search through the Database handle → (p50 µs, cost, the
    resolved QueryPlan for the emitted record)."""
    db = Database.wrap(st)
    us = time_call(lambda q: db.query(q, k=_K).ids, queries)
    res = db.query(queries, k=_K)
    return us, res.cost, res.plan


def run() -> None:
    ds, index = fatrq_index()
    st = StreamingIndex(index, StreamingConfig(auto_compact=False))
    q = ds.queries
    nq = q.shape[0]
    stream = np.asarray(make_embeddings(jax.random.PRNGKey(42), 8000,
                                        ds.x.shape[1]))

    # --- insert rate vs batch size (amortized µs/row, includes encode)
    off = 0
    for batch in (64, 512, 4096):
        x_new = stream[off:off + batch]
        off += batch
        t0 = time.perf_counter()
        st.insert(x_new)
        jax.block_until_ready(st.x)
        dt = time.perf_counter() - t0
        emit(f"stream_insert_b{batch}_us_per_row", dt / batch * 1e6,
             f"rows_per_s={batch / dt:.0f}", batch=batch,
             rows_per_s=batch / dt)

    # --- search latency vs delta fraction (fresh index per point)
    for frac in (0.0, 0.1, 0.25):
        stf = StreamingIndex(index, StreamingConfig(auto_compact=False))
        n_ins = int(frac * len(stf))
        if n_ins:
            stf.insert(stream[:n_ins])
        us, cost, plan = _p50_search(stf, q)
        t = cost.total_seconds()
        delta_b = sum(tr.bytes for k, tr in cost.ledger.items()
                      if k.startswith("delta:"))
        emit(f"stream_search_delta{int(frac * 100)}pct_us", us / nq,
             f"qps_model={nq / t:.0f};delta_B={delta_b}", cost=cost,
             plan=plan, qps=nq / t, delta_frac=frac)

    # --- search latency vs tombstone fraction, then compaction
    stt = StreamingIndex(index, StreamingConfig(auto_compact=False))
    stt.insert(stream[:2000])
    rng = np.random.default_rng(0)
    n0 = len(stt)
    for frac in (0.1, 0.25):
        target = int(frac * n0) - stt.n_tombstones
        live = np.fromiter(stt._gid_row.keys(), np.int64)
        stt.delete(rng.choice(live, size=target, replace=False))
        us, cost, plan = _p50_search(stt, q)
        t = cost.total_seconds()
        emit(f"stream_search_tomb{int(frac * 100)}pct_us", us / nq,
             f"qps_model={nq / t:.0f}", cost=cost, plan=plan, qps=nq / t,
             tombstone_frac=stt.drift()["tombstone_frac"])

    t0 = time.perf_counter()
    stats = stt.compact()
    jax.block_until_ready(stt.x)
    dt = time.perf_counter() - t0
    emit("stream_compact_us_per_row", dt / max(stats["n_live"], 1) * 1e6,
         f"folded={stats['folded_delta_rows']};"
         f"dropped={stats['dropped_tombstones']}", **stats)
    us, cost, plan = _p50_search(stt, q)
    emit("stream_search_post_compact_us", us / nq,
         f"qps_model={nq / cost.total_seconds():.0f}", cost=cost,
         plan=plan, qps=nq / cost.total_seconds())


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
    write_json("bench_streaming")
