"""Adaptive tiered-placement benchmark: heat-driven hot/cold migration
under a seeded Zipfian trace vs uniform traffic.

Replays the same query trace twice through a ``TieredIndex`` — once on
the all-warm (static-equivalent) placement, once after
``rebalance_tiers()`` promoted the hottest lists to HBM and demoted the
coldest to SSD — for two traffic shapes:

* ``uniform`` — the query pool spreads evenly over the IVF lists; there
  is no head to promote, so adaptive placement buys little (and the cold
  demotions can even cost: SSD's 4 KiB min-grain bills every stray probe
  into a demoted list).
* ``skewed``  — a seeded Zipfian trace (popularity ∝ rank^-1.3 over rows
  ranked by distance to one anchor) concentrates probes on a handful of
  lists; the policy moves that head into HBM and the modeled time drops.

Every number is from the Table-I tier model over a seeded trace, so the
records are exactly reproducible and gate hard in CI
(``scripts/check_bench.py --bench tiered``), including the headline
invariant ``tiered_skewed_policy < tiered_skewed_warm``.  Records carry
no ``devices`` field on purpose: the tiered datapath is per-device, so
both CI device legs must reproduce the SAME numbers against one
baseline.

Standalone: ``python benchmarks/bench_tiered.py [--queries N]``.  Writes
``BENCH_bench_tiered.json``.
"""

from __future__ import annotations

import sys

if __name__ == "__main__":          # must run BEFORE anything imports jax
    import argparse
    import os

    _ap = argparse.ArgumentParser()
    _ap.add_argument("--devices", type=int, default=None,
                     help="fake this many host devices (the tiered bench "
                          "is per-device; this only proves the numbers "
                          "are device-count invariant)")
    _ap.add_argument("--queries", type=int, default=64,
                     help="queries per trace")
    _CLI_ARGS = _ap.parse_args()
    if _CLI_ARGS.devices and _CLI_ARGS.devices > 1 and \
            "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={_CLI_ARGS.devices}"
        ).strip()
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [os.path.join(_root, "src"), _root]

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, fatrq_index, write_json
from repro.anns import (Database, QueryPlan, TieredConfig, TieredIndex,
                        recall_at_k)
from repro.data.synthetic import brute_force_topk

_K = 10
_POLICY = TieredConfig(decay=0.5, hot_rows_frac=0.25, cold_rows_frac=0.2)


def _uniform_trace(ds, n: int) -> jnp.ndarray:
    """Seeded uniform replay over the held-out query set."""
    rng = np.random.default_rng(3)
    pool = np.asarray(ds.queries)
    return jnp.asarray(pool[rng.integers(0, pool.shape[0], size=n)])


def _zipfian_trace(ds, n: int) -> jnp.ndarray:
    """Seeded Zipfian replay: popularity ∝ rank^-1.3 over database rows
    ranked by distance to one anchor, so the head lands on few lists."""
    x = np.asarray(ds.x)
    near = np.argsort(((x - x[0]) ** 2).sum(axis=1))
    rng = np.random.default_rng(11)
    p = 1.0 / np.arange(1, len(near) + 1, dtype=np.float64) ** 1.3
    rows = near[rng.choice(len(near), size=n, p=p / p.sum())]
    q = x[rows] + 0.02 * rng.standard_normal((n, x.shape[1]))
    return jnp.asarray((q / np.linalg.norm(q, axis=1, keepdims=True))
                       .astype(np.float32))


def _replay(shape: str, ds, index, queries) -> None:
    """One trace through all-warm then policy-on placement; two records."""
    ti = TieredIndex(index, _POLICY)
    db = Database.wrap(ti)
    plan = QueryPlan(front="ivf", k=_K)
    gt = brute_force_topk(ds.x, queries, _K)
    nq = queries.shape[0]

    warm = db.query(queries, plan=plan)       # all-warm pass builds heat
    out = ti.rebalance_tiers()
    policy = db.query(queries, plan=plan)

    for name, res in (("warm", warm), ("policy", policy)):
        occ = out["occupancy"] if name == "policy" else \
            {"hot": (0, 0),
             "warm": (ti.list_tier.shape[0], int(ti.list_rows.sum())),
             "cold": (0, 0)}
        total = res.cost.total_seconds()
        emit(f"tiered_{shape}_{name}", total / nq * 1e6,
             f"recall@{_K}={recall_at_k(res.ids, gt, _K):.3f};"
             f"hot_rows={occ['hot'][1]};cold_rows={occ['cold'][1]}",
             cost=res.cost, plan=res.plan,
             recall_at_k=float(recall_at_k(res.ids, gt, _K)),
             n_queries=int(nq),
             hot_lists=occ["hot"][0], hot_rows=occ["hot"][1],
             cold_lists=occ["cold"][0], cold_rows=occ["cold"][1],
             generation=ti.generation)


def run(*, devices: int | None = None, n_queries: int = 64) -> None:
    del devices  # per-device datapath: records are device-count invariant
    ds, index = fatrq_index()
    _replay("uniform", ds, index, _uniform_trace(ds, n_queries))
    _replay("skewed", ds, index, _zipfian_trace(ds, n_queries))


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(devices=_CLI_ARGS.devices, n_queries=_CLI_ARGS.queries)
    write_json("bench_tiered")
