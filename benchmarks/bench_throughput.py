"""Fig. 6 — end-to-end throughput: FaTRQ-SW / FaTRQ-HW vs SSD-rerank
baseline, on IVF and CAGRA front stages, at matched recall.

Absolute times come from the Table-I tier cost model (the container has no
CXL/SSD on the hot path — same methodology as the paper's Ramulator +
datasheet simulation).  -SW places residual codes in CXL memory with host
filtering (codes cross the CXL link, host CPU scores them); -HW offloads
filtering into the CXL Type-2 accelerator (device-local access, 3.7×
faster filtering per §V-B, only 4 B coarse distances + survivor ids cross
the link).
"""

from __future__ import annotations

from benchmarks.common import dataset, emit, fatrq_index
from repro.anns import make_executor, recall_at_k
from repro.memory import QueryCost

# host-CPU vs accelerator per-candidate filtering cost (calibrated to the
# paper's "filtering up to 3.7× faster" §V-B; 40-thread Xeon scoring a
# 154 B ternary code ≈ 45 ns/candidate amortized)
_SW_NS_PER_CAND = 45.0
_HW_NS_PER_CAND = 45.0 / 3.7


def _fatrq_cost(index, queries, *, hw: bool, front: str = "ivf"
                ) -> tuple[float, QueryCost]:
    ex = make_executor(index, front=front)
    pred, cost = ex.search(queries, k=10)
    rec = recall_at_k(pred, dataset().gt, 10)
    # replace the generic compute estimate with the mode-specific one
    total_cand = sum(t.accesses for k_, t in cost.ledger.items()
                     if k_.startswith("refine"))
    cost.compute_s = total_cand * (
        _HW_NS_PER_CAND if hw else _SW_NS_PER_CAND) * 1e-9
    if hw:
        # -HW: codes never cross the CXL link to the host; scoring happens
        # in-device.  Model: refine traffic billed at device-internal DRAM
        # timing instead of the host-visible CXL link.
        for key in list(cost.ledger):
            if key.startswith("refine:cxl"):
                t = cost.ledger.pop(key)
                cost.ledger[key.replace("cxl", "dram")] = t
    return rec, cost


def run() -> None:
    ds, index = fatrq_index()
    q = ds.queries

    # --- IVF front stage
    base_pred, base_cost = make_executor(index).search_baseline(q, k=10)
    base_rec = recall_at_k(base_pred, ds.gt, 10)
    t_base = base_cost.total_seconds()

    rec_sw, cost_sw = _fatrq_cost(index, q, hw=False)
    rec_hw, cost_hw = _fatrq_cost(index, q, hw=True)
    t_sw, t_hw = cost_sw.total_seconds(), cost_hw.total_seconds()

    nq = q.shape[0]
    emit("fig6_ivf_baseline_qps", t_base / nq * 1e6,
         f"recall={base_rec:.3f}")
    emit("fig6_ivf_fatrq_sw_qps", t_sw / nq * 1e6,
         f"recall={rec_sw:.3f};speedup={t_base / t_sw:.2f}x")
    emit("fig6_ivf_fatrq_hw_qps", t_hw / nq * 1e6,
         f"recall={rec_hw:.3f};speedup={t_base / t_hw:.2f}x;"
         f"hw_over_sw={t_sw / t_hw:.2f}x")

    # --- CAGRA-style graph front stage through the same executor (fewer
    # candidates → smaller gain, matching the paper's IVF-vs-CAGRA ordering)
    gex = make_executor(index, front="graph")
    gbase_pred, cost_gb = gex.search_baseline(q, k=10)
    gbase_rec = recall_at_k(gbase_pred, ds.gt, 10)
    t_gbase = cost_gb.total_seconds()

    rec_gf, cost_gf = _fatrq_cost(index, q, hw=True, front="graph")
    t_gf = cost_gf.total_seconds()
    emit("fig6_cagra_baseline_qps", t_gbase / nq * 1e6,
         f"recall={gbase_rec:.3f}")
    emit("fig6_cagra_fatrq_hw_qps", t_gf / nq * 1e6,
         f"recall={rec_gf:.3f};speedup={t_gbase / t_gf:.2f}x")


if __name__ == "__main__":
    run()
