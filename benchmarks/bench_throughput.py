"""Fig. 6 — end-to-end throughput: FaTRQ-SW / FaTRQ-HW vs SSD-rerank
baseline, on IVF and CAGRA front stages, at matched recall — plus the
scale-out sweep: the same database sharded 1/2/4/8 ways across a
host-platform ``("search",)`` mesh through ``anns.sharding``.

Absolute times come from the Table-I tier cost model (the container has no
CXL/SSD on the hot path — same methodology as the paper's Ramulator +
datasheet simulation).  -SW places residual codes in CXL memory with host
filtering (codes cross the CXL link, host CPU scores them); -HW offloads
filtering into the CXL Type-2 accelerator (device-local access, 3.7×
faster filtering per §V-B, only 4 B coarse distances + survivor ids cross
the link).  Sharded times fold per-shard ledgers with
``QueryCost.merge_parallel`` (slowest lane bounds the batch), so the sweep
shows the parallel-shard speedup the paper reaches by replicating
far-memory channels.

Standalone: ``python benchmarks/bench_throughput.py --shards 8`` fakes 8
host devices (must be set before jax initializes) and writes
``BENCH_bench_throughput.json``; ``--front graph`` runs the scale-out
sweep through the halo-partitioned graph datapath instead of the IVF
whole-list partitioner (records named ``fig6_sharded_graph_{s}x_qps``).
"""

from __future__ import annotations

import sys

if __name__ == "__main__":          # must run BEFORE anything imports jax
    import argparse
    import os

    _ap = argparse.ArgumentParser()
    _ap.add_argument("--shards", type=int, default=None,
                     help="max shard count for the scale-out sweep; fakes "
                          "that many host devices")
    _ap.add_argument("--front", choices=("ivf", "graph"), default="ivf",
                     help="front stage for the scale-out sweep (the fixed "
                          "IVF/CAGRA single-device figures always run)")
    _CLI_ARGS = _ap.parse_args()
    if _CLI_ARGS.shards and _CLI_ARGS.shards > 1 and \
            "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={_CLI_ARGS.shards}"
        ).strip()
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [os.path.join(_root, "src"), _root]

import jax

from benchmarks.common import dataset, emit, fatrq_index, write_json
from repro.anns import Database, QueryPlan, recall_at_k
from repro.memory import QueryCost

# host-CPU vs accelerator per-candidate filtering cost (calibrated to the
# paper's "filtering up to 3.7× faster" §V-B; 40-thread Xeon scoring a
# 154 B ternary code ≈ 45 ns/candidate amortized)
_SW_NS_PER_CAND = 45.0
_HW_NS_PER_CAND = 45.0 / 3.7


def _fatrq_cost(index, queries, *, hw: bool, front: str = "ivf"
                ) -> tuple[float, QueryCost, QueryPlan]:
    res = Database.wrap(index).query(queries,
                                     plan=QueryPlan(front=front, k=10))
    pred, cost = res.ids, res.cost
    rec = recall_at_k(pred, dataset().gt, 10)
    # replace the generic compute estimate with the mode-specific one
    total_cand = sum(t.accesses for k_, t in cost.ledger.items()
                     if k_.startswith("refine"))
    cost.compute_s = total_cand * (
        _HW_NS_PER_CAND if hw else _SW_NS_PER_CAND) * 1e-9
    if hw:
        # -HW: codes never cross the CXL link to the host; scoring happens
        # in-device.  Model: refine traffic billed at device-internal DRAM
        # timing instead of the host-visible CXL link.
        for key in list(cost.ledger):
            if key.startswith("refine:cxl"):
                t = cost.ledger.pop(key)
                cost.ledger[key.replace("cxl", "dram")] = t
    return rec, cost, res.plan


def _shard_sweep(ds, db: Database, *, max_shards: int | None,
                 front: str = "ivf") -> None:
    """Scale-out: shard the database across the host-platform mesh and
    report model-time QPS per shard count (parallel-shard fold).  The
    ``front`` selects the partitioner + in-shard datapath — whole-list LPT
    for IVF, vector ranges + halo frontier exchange for graph — and tags
    the emitted record names so both sweeps coexist in one JSON."""
    q = ds.queries
    nq = q.shape[0]
    avail = len(jax.devices())
    limit = min(max_shards or avail, avail, db.index.ivf.nlist)
    counts = [s for s in (1, 2, 4, 8, 16) if s <= limit]
    tag = "" if front == "ivf" else f"{front}_"
    t1 = None
    for s in counts:
        res = db.query(q, plan=QueryPlan(front=front, shards=s, k=10))
        rec = recall_at_k(res.ids, ds.gt, 10)
        t = res.cost.total_seconds()
        t1 = t if t1 is None else t1
        emit(f"fig6_sharded_{tag}{s}x_qps", t / nq * 1e6,
             f"recall={rec:.3f};scaleup={t1 / t:.2f}x", cost=res.cost,
             plan=res.plan, qps=nq / t, shards=s, front=front)


def run(*, max_shards: int | None = None, front: str = "ivf") -> None:
    ds, index = fatrq_index()
    db = Database.wrap(index)
    q = ds.queries

    # --- IVF front stage
    base = db.query(q, plan=QueryPlan(k=10, mode="baseline"))
    base_rec = recall_at_k(base.ids, ds.gt, 10)
    base_cost = base.cost
    t_base = base_cost.total_seconds()

    rec_sw, cost_sw, plan_sw = _fatrq_cost(index, q, hw=False)
    rec_hw, cost_hw, plan_hw = _fatrq_cost(index, q, hw=True)
    t_sw, t_hw = cost_sw.total_seconds(), cost_hw.total_seconds()

    nq = q.shape[0]
    emit("fig6_ivf_baseline_qps", t_base / nq * 1e6,
         f"recall={base_rec:.3f}", cost=base_cost, plan=base.plan,
         qps=nq / t_base)
    emit("fig6_ivf_fatrq_sw_qps", t_sw / nq * 1e6,
         f"recall={rec_sw:.3f};speedup={t_base / t_sw:.2f}x",
         cost=cost_sw, plan=plan_sw, qps=nq / t_sw)
    emit("fig6_ivf_fatrq_hw_qps", t_hw / nq * 1e6,
         f"recall={rec_hw:.3f};speedup={t_base / t_hw:.2f}x;"
         f"hw_over_sw={t_sw / t_hw:.2f}x", cost=cost_hw, plan=plan_hw,
         qps=nq / t_hw)

    # --- CAGRA-style graph front stage through the same executor (fewer
    # candidates → smaller gain, matching the paper's IVF-vs-CAGRA ordering)
    gbase = db.query(q, plan=QueryPlan(front="graph", k=10,
                                       mode="baseline"))
    gbase_rec = recall_at_k(gbase.ids, ds.gt, 10)
    t_gbase = gbase.cost.total_seconds()

    rec_gf, cost_gf, plan_gf = _fatrq_cost(index, q, hw=True, front="graph")
    t_gf = cost_gf.total_seconds()
    emit("fig6_cagra_baseline_qps", t_gbase / nq * 1e6,
         f"recall={gbase_rec:.3f}", cost=gbase.cost, plan=gbase.plan,
         qps=nq / t_gbase)
    emit("fig6_cagra_fatrq_hw_qps", t_gf / nq * 1e6,
         f"recall={rec_gf:.3f};speedup={t_gbase / t_gf:.2f}x",
         cost=cost_gf, plan=plan_gf, qps=nq / t_gf)

    # --- scale-out sweep through the sharded subsystem
    _shard_sweep(ds, db, max_shards=max_shards, front=front)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(max_shards=_CLI_ARGS.shards, front=_CLI_ARGS.front)
    write_json("bench_throughput")
