"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit) and
writes one machine-readable ``BENCH_<module>.json`` per bench (per-row
timing, QPS where applicable, and QueryCost breakdowns) so the perf
trajectory is tracked across PRs.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_arch_dims, bench_distortion,
                            bench_kernels, bench_refinement, bench_serving,
                            bench_storage, bench_streaming,
                            bench_throughput, bench_tiered, common)

    print("name,us_per_call,derived")
    failures = 0
    for mod in [bench_storage, bench_arch_dims, bench_kernels,
                bench_distortion, bench_throughput, bench_refinement,
                bench_streaming, bench_tiered, bench_serving]:
        short = mod.__name__.rsplit(".", 1)[-1]
        try:
            mod.run()
            common.write_json(short)
        except Exception:
            common.take_records()    # drop partial records of the failure
            failures += 1
            print(f"# FAILED {mod.__name__}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
