"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_arch_dims, bench_distortion,
                            bench_kernels, bench_refinement, bench_storage,
                            bench_throughput)

    print("name,us_per_call,derived")
    failures = 0
    for mod in [bench_storage, bench_arch_dims, bench_kernels,
                bench_distortion, bench_throughput, bench_refinement]:
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"# FAILED {mod.__name__}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
