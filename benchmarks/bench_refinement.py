"""Fig. 8 — recall@10 vs refinement ratio (SSD fetches / k) — plus the
staged-executor sweep: per-backend (reference jnp vs fused Pallas kernel),
per-front-stage (IVF probe vs graph beam) timing and QueryCost breakdown.

Baseline: rerank candidates in PQ-distance order (the yellow curve —
recovering true top-10 at 99% needs ~70 of 100 candidates).  FaTRQ: rerank
in calibrated-estimate order — the same recall within ~25 (2.8× less SSD).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, time_call
from repro.anns import Database, PipelineConfig, QueryPlan, recall_at_k
from repro.anns.executor import FRONT_STAGES, REFINE_BACKENDS
from repro.core import (calibrate, encode_database, exact_distance_sq,
                        residual_ip_estimate, unpack_level)
from repro.core.calibration import build_features, predict
from repro.data import make_dataset
from repro.quant import pq as pq_mod


def run_backends(n: int = 8000, d: int = 64, nq: int = 32) -> None:
    """Executor sweep: front ∈ {ivf, graph} × backend ∈ {reference, pallas}.

    Emits wall time per query plus the Table-I QueryCost breakdown per
    combination.  (The Pallas kernel runs in interpret mode on CPU
    containers — wall times there measure the emulation, not TPU perf; the
    QueryCost columns are the hardware-model numbers either way.)
    """
    ds = make_dataset(jax.random.PRNGKey(0), n=n, d=d, n_queries=nq,
                      k_gt=100, clusters=32)
    cfg = PipelineConfig(dim=d, pq_m=d // 8, pq_k=64, nlist=32, nprobe=8,
                         final_k=10, refine_budget=40)
    db = Database.build(jax.random.PRNGKey(1), ds.x, cfg)
    for front in FRONT_STAGES:
        for backend in REFINE_BACKENDS:
            plan = QueryPlan(front=front, backend=backend, k=10)
            us = time_call(lambda: db.query(ds.queries, plan=plan).ids,
                           iters=3, warmup=1)
            res = db.query(ds.queries, plan=plan)
            rec = recall_at_k(res.ids, ds.gt, 10)
            bd = res.cost.breakdown()
            detail = ";".join(f"{t}={v * 1e6 / nq:.3f}us"
                              for t, v in bd.items() if v > 0)
            emit(f"executor_{front}_{backend}", us / nq,
                 f"recall={rec:.3f};model_total="
                 f"{res.cost.total_seconds() * 1e6 / nq:.3f}us;{detail}",
                 cost=res.cost, plan=res.plan)


def run(n: int = 20_000, d: int = 128, top: int = 100) -> None:
    run_backends()
    ds = dataset(n, d)
    x, q_all, gt = ds.x, ds.queries, ds.gt

    cb = pq_mod.train(jax.random.PRNGKey(3), x, m=d // 8, k=256, iters=8)
    codes = pq_mod.encode(cb, x)
    x_c = pq_mod.decode(cb, codes)
    trq, _ = encode_database(x, x_c)
    # §III-E calibration pairs: sampled records × their index neighbors
    from repro.data import brute_force_topk
    samp = jax.random.choice(jax.random.PRNGKey(5), n, (200,),
                             replace=False)
    neigh = brute_force_topk(x, x[samp], 16)[:, 1:]
    cols = jax.random.randint(jax.random.PRNGKey(6), (200, 2), 0, 15)
    pair = jnp.take_along_axis(neigh, cols, axis=1).reshape(-1)
    qs = jnp.repeat(x[samp], 2, axis=0)
    trq = calibrate(trq, qs, x, x_c, pair)

    sc = trq.scalars
    code0 = unpack_level(trq, 0)

    def recall_curve(order_scores_fn):
        """order candidates by score; recall@10 after fetching top-r."""
        hits = {r: 0 for r in FETCHES}
        for i in range(q_all.shape[0]):
            q = q_all[i]
            # candidate list = top-`top` by PQ distance (paper's setup)
            table = pq_mod.adc_table(cb, q)
            d_pq = pq_mod.adc_distances(table, codes)
            cand = jnp.argsort(d_pq)[:top]
            scores = order_scores_fn(q, cand)
            order = cand[jnp.argsort(scores)]
            true10 = set(np.asarray(gt[i, :10]).tolist())
            for r in FETCHES:
                got = set(np.asarray(order[:r]).tolist())
                # exact rerank of the fetched r → top-10 of those
                fetched = np.asarray(order[:r])
                dd = np.asarray(exact_distance_sq(q, x[fetched]))
                top10 = set(fetched[np.argsort(dd)[:10]].tolist())
                hits[r] += len(top10 & true10) / 10
        return {r: hits[r] / q_all.shape[0] for r in FETCHES}

    FETCHES = [10, 15, 20, 25, 40, 70, 100]

    def pq_order(q, cand):
        table = pq_mod.adc_table(cb, q)
        return pq_mod.adc_distances(table, codes[cand])

    def fatrq_order(q, cand):
        d0 = jnp.sum((q[None] - x_c[cand]) ** 2, axis=-1)
        d_ip = residual_ip_estimate(q, code0[cand], sc.norm[cand],
                                    sc.rho[cand])
        feats = build_features(d0, d_ip, sc.delta_sq[cand], sc.cross[cand])
        return predict(trq.model, feats)

    base = recall_curve(pq_order)
    fat = recall_curve(fatrq_order)
    for r in FETCHES:
        emit(f"fig8_recall_at_fetch{r}", 0.0,
             f"baseline={base[r]:.3f};fatrq={fat[r]:.3f}")
    # headline: fetches needed at matched recall (paper uses 0.99 on real
    # data; our synthetic curves saturate at ~0.98, so compare at 0.95)
    for thresh, tag in [(0.95, "95pct"), (0.99, "99pct")]:
        need_b = min((r for r in FETCHES if base[r] >= thresh),
                     default=None)
        need_f = min((r for r in FETCHES if fat[r] >= thresh),
                     default=None)
        if need_b and need_f:
            emit(f"fig8_fetches_for_{tag}", 0.0,
                 f"baseline={need_b};fatrq={need_f};"
                 f"reduction={need_b / need_f:.2f}x")


if __name__ == "__main__":
    run()
