"""Kernel micro-benchmarks (interpret mode on CPU — correctness-path
timing; real TPU timing comes from the roofline analysis) + the kernel's
HBM-traffic advantage, which is hardware-independent arithmetic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core.packing import pack_ternary, packed_size
from repro.core.ternary import ternary_encode
from repro.kernels.ops import adc_scores, refine_scores


def run(c: int = 4096, d: int = 768) -> None:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (c, d))
    delta = 0.2 * jax.random.normal(ks[1], (c, d))
    tc = ternary_encode(delta)
    packed = pack_ternary(tc.code)
    q = jax.random.normal(ks[2], (d,))
    d0 = jnp.abs(jax.random.normal(ks[3], (c,)))
    zeros = jnp.zeros((c,))
    w = jnp.asarray([1.0, 1.0, 1.0, 2.0])

    us = time_call(refine_scores, packed, q, d0, zeros, zeros, tc.norm,
                   tc.rho, w, jnp.asarray(0.0), iters=3)
    emit("kernel_ternary_refine_us", us, f"candidates={c};dim={d}")

    codes = jax.random.randint(key, (c, 96), 0, 256).astype(jnp.uint8)
    lut = jax.random.uniform(ks[1], (96, 256))
    us = time_call(adc_scores, codes, lut, iters=3)
    emit("kernel_pq_adc_us", us, f"candidates={c};m=96")

    # HBM traffic per candidate: packed ternary vs full-precision fetch
    far = packed_size(d) + 20
    full = d * 4
    emit("kernel_refine_hbm_bytes_per_cand", 0.0,
         f"fatrq={far};full_fetch={full};saving={full / far:.1f}x")


if __name__ == "__main__":
    run()
