"""Kernel micro-benchmarks (interpret mode on CPU — correctness-path
timing; real TPU timing comes from the roofline analysis) + the kernel's
HBM-traffic advantage, which is hardware-independent arithmetic.

Covers the single-query and batched level-0 kernels, the fused persistent
multi-level kernel vs the pre-fusion datapath it replaced (level-0 kernel
+ pure-jnp deeper levels with HBM round-trips between levels), and a
``block_c`` autotune sweep over the fused kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call, write_json
from repro.core import trq as trq_mod
from repro.core.packing import pack_ternary, packed_size, unpack_ternary
from repro.core.ternary import ternary_encode, ternary_inner
from repro.kernels.ops import (adc_scores, fused_refine_scores_batch,
                               refine_scores, refine_scores_batch)


def _trq_problem(nq: int, c: int, d: int, levels: int):
    """Calibrated multi-level refine inputs in the fused wrapper's layout."""
    key = jax.random.PRNGKey(0)
    kx, kc, kq, kcal, kp = jax.random.split(key, 5)
    x = jax.random.normal(kx, (c, d))
    cents = jax.random.normal(kc, (16, d))
    assign = jnp.argmin(jnp.sum((x[:, None] - cents[None]) ** 2, -1), -1)
    x_c = cents[assign]
    codes, _ = trq_mod.encode_database(x, x_c, num_levels=levels)
    qcal = jax.random.normal(kcal, (64, d))
    pair = jax.random.randint(kp, (64,), 0, c)
    codes = trq_mod.calibrate(codes, qcal, x, x_c, pair)
    qs = jax.random.normal(kq, (nq, d))
    ids = jnp.broadcast_to(jnp.arange(c)[None], (nq, c))
    valid = jnp.ones((nq, c), bool)
    d0 = jnp.sum((x_c[ids] - qs[:, None]) ** 2, -1)
    sc = codes.scalars
    return (codes, (jnp.stack([lv.packed[ids] for lv in codes.levels]), qs,
                    d0, sc.delta_sq[ids], sc.cross[ids], sc.norm[ids],
                    sc.rho[ids], valid, jnp.zeros_like(valid),
                    jnp.stack([lv.proj[ids] for lv in codes.levels]),
                    jnp.stack([lv.norm[ids] for lv in codes.levels]),
                    jnp.stack([lv.rho[ids] for lv in codes.levels]),
                    codes.model.w, codes.model.bias, codes.model.resid_std,
                    3.0))


@functools.partial(jax.jit, static_argnames=("k", "block_c", "dim"))
def _prefusion_refine(packed_levels, qs, d0, delta_sq, cross, norm, rho,
                      valid, _is_delta, lvl_proj, lvl_norm, lvl_rho, w,
                      bias, resid_std, _z, *, k: int, block_c: int,
                      dim: int):
    """The datapath the fused kernel replaced: level-0 Pallas kernel, then
    pure-jnp unpack + stacking per deeper level, estimates and alive masks
    round-tripping through HBM between levels (cauchy bound)."""
    from repro.core.estimator import pooled_k_smallest
    out = refine_scores_batch(packed_levels[0], qs, d0, delta_sq, cross,
                              norm, rho, w, bias, block_c=block_c)
    est, est_raw, margin = out[..., 0], out[..., 1], out[..., 2]
    lo, hi = est_raw - margin, est_raw + margin
    tau = pooled_k_smallest(jnp.where(valid, hi, jnp.inf), k, None)
    alive = valid & (lo <= tau[:, None])
    qn = jnp.linalg.norm(qs, axis=-1, keepdims=True)
    for lv in range(1, packed_levels.shape[0]):
        trits = unpack_ternary(packed_levels[lv], dim)
        align = ternary_inner(trits, qs[:, None, :])
        est = est - 2.0 * lvl_proj[lv] * align
        rem = lvl_norm[lv] * jnp.sqrt(
            jnp.clip(1.0 - lvl_rho[lv] ** 2, 0.0, 1.0))
        marg = 2.0 * qn * rem + resid_std
        tau = pooled_k_smallest(jnp.where(alive, est + marg, jnp.inf), k,
                                None)
        alive = alive & (est - marg <= tau[:, None])
    return est, alive


def run(c: int = 4096, d: int = 768) -> None:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (c, d))
    delta = 0.2 * jax.random.normal(ks[1], (c, d))
    tc = ternary_encode(delta)
    packed = pack_ternary(tc.code)
    q = jax.random.normal(ks[2], (d,))
    d0 = jnp.abs(jax.random.normal(ks[3], (c,)))
    zeros = jnp.zeros((c,))
    w = jnp.asarray([1.0, 1.0, 1.0, 2.0])

    us = time_call(refine_scores, packed, q, d0, zeros, zeros, tc.norm,
                   tc.rho, w, jnp.asarray(0.0), iters=3)
    emit("kernel_ternary_refine_us", us, f"candidates={c};dim={d}")

    # batched level-0 kernel: the executor's per-micro-batch launch
    nq_b = 4
    us = time_call(refine_scores_batch,
                   jnp.broadcast_to(packed, (nq_b, c, packed.shape[1])),
                   jax.random.normal(ks[2], (nq_b, d)),
                   jnp.broadcast_to(d0, (nq_b, c)),
                   jnp.zeros((nq_b, c)), jnp.zeros((nq_b, c)),
                   jnp.broadcast_to(tc.norm, (nq_b, c)),
                   jnp.broadcast_to(tc.rho, (nq_b, c)), w,
                   jnp.asarray(0.0), iters=3)
    emit("kernel_ternary_refine_batch_us", us,
         f"queries={nq_b};candidates={c};dim={d}")

    codes = jax.random.randint(key, (c, 96), 0, 256).astype(jnp.uint8)
    lut = jax.random.uniform(ks[1], (96, 256))
    us = time_call(adc_scores, codes, lut, iters=3)
    emit("kernel_pq_adc_us", us, f"candidates={c};m=96")

    # fused persistent multi-level kernel vs the pre-fusion datapath
    nq_f, c_f, d_f, levels, k = 4, 2048, 256, 3, 10
    _, args = _trq_problem(nq_f, c_f, d_f, levels)
    fused = functools.partial(fused_refine_scores_batch, k=k,
                              bound="cauchy", block_c=512)
    us_fused = time_call(fused, *args, iters=3)
    emit("kernel_fused_refine_us", us_fused,
         f"queries={nq_f};candidates={c_f};dim={d_f};levels={levels}",
         levels=levels, block_c=512)
    prefusion = functools.partial(_prefusion_refine, k=k, block_c=512,
                                  dim=d_f)
    us_pre = time_call(prefusion, *args, iters=3)
    emit("kernel_l0_plus_jnp_refine_us", us_pre,
         f"queries={nq_f};candidates={c_f};dim={d_f};levels={levels};"
         f"fused_speedup={us_pre / us_fused:.2f}x",
         levels=levels, fused_speedup=us_pre / us_fused)

    # block_c autotune sweep over the fused kernel (level tiling is the
    # grid's middle dimension — every block_c covers all levels in one
    # launch, so the sweep is the full fused-kernel tuning space)
    for bc in (128, 256, 512, 1024):
        f = functools.partial(fused_refine_scores_batch, k=k,
                              bound="cauchy", block_c=bc)
        us = time_call(f, *args, iters=3)
        emit(f"kernel_fused_refine_block{bc}_us", us,
             f"queries={nq_f};candidates={c_f};dim={d_f};levels={levels};"
             f"block_c={bc}", levels=levels, block_c=bc)

    # HBM traffic per candidate: packed ternary vs full-precision fetch
    far = packed_size(d) + 20
    full = d * 4
    emit("kernel_refine_hbm_bytes_per_cand", 0.0,
         f"fatrq={far};full_fetch={full};saving={full / far:.1f}x")


if __name__ == "__main__":
    run()
    write_json("bench_kernels")
