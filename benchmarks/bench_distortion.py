"""Fig. 7 — distance-estimation distortion vs the top-100 ground truth:
INT8 (w/o RQ), PQ + 3-bit SQ residuals, PQ + FaTRQ ternary residuals,
oracle (full-precision residuals).  Paper: FaTRQ MSE 0.0159 vs SQ3 0.258.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit
from repro.core import (calibrate, encode_database, exact_distance_sq,
                        residual_ip_estimate, unpack_level)
from repro.core.calibration import build_features, predict
from repro.quant import pq as pq_mod
from repro.quant import sq as sq_mod


def run(d: int = 768, n: int = 8000) -> None:
    ds = dataset(n, d, 32)
    x, q_all, gt = ds.x, ds.queries, ds.gt

    key = jax.random.PRNGKey(3)
    cb = pq_mod.train(key, x, m=d // 8, k=256, iters=8)
    codes = pq_mod.encode(cb, x)
    x_c = pq_mod.decode(cb, codes)

    trq, _ = encode_database(x, x_c, num_levels=1)
    # §III-E calibration pairs: sampled records paired with their INDEX
    # NEIGHBORS (not themselves!) — the pair distances then match the
    # query-to-candidate scale near the top-k boundary.
    from repro.data import brute_force_topk
    samp = jax.random.choice(jax.random.PRNGKey(5), n, (200,),
                             replace=False)
    neigh = brute_force_topk(x, x[samp], 16)[:, 1:]       # drop self
    cols = jax.random.randint(jax.random.PRNGKey(6), (200, 2), 0, 15)
    pair = jnp.take_along_axis(neigh, cols, axis=1).reshape(-1)
    qs = jnp.repeat(x[samp], 2, axis=0)
    trq = calibrate(trq, qs, x, x_c, pair)

    delta = x - x_c
    # BANG-style residual SQ: one GLOBAL range for the whole dataset (codes
    # carry no per-record metadata) — the paper's comparator; plus the
    # stronger per-record-range variant as an upgraded baseline.
    levels3 = 7
    glo = jnp.quantile(jnp.abs(delta), 0.999)
    step = 2 * glo / levels3
    q3g = jnp.clip(jnp.round((delta + glo) / step), 0, levels3)
    delta_sq3_global = q3g * step - glo
    sq3 = sq_mod.sq_encode(delta, 3)
    delta_sq3 = sq_mod.sq_decode(sq3)
    int8 = sq_mod.int8_encode(x)
    x_int8 = sq_mod.sq_decode(int8)

    def norm_mse(errs, trues):
        # normalized squared error (relative to mean true distance), the
        # scale-free form of Fig. 7's distortion
        scale = float(jnp.mean(trues))
        return float(jnp.mean(((errs - trues) / scale) ** 2))

    e_fatrq, e_sq3, e_sq3_pr, e_int8, e_oracle, trues = \
        [], [], [], [], [], []
    sc = trq.scalars
    code0 = unpack_level(trq, 0)
    for i in range(q_all.shape[0]):
        q = q_all[i]
        idx = gt[i]                      # top-100 true neighbors
        true_d = exact_distance_sq(q, x[idx])
        trues.append(true_d)
        d0 = jnp.sum((q[None] - x_c[idx]) ** 2, axis=-1)
        # FaTRQ calibrated estimate
        d_ip = residual_ip_estimate(q, code0[idx], sc.norm[idx],
                                    sc.rho[idx])
        feats = build_features(d0, d_ip, sc.delta_sq[idx], sc.cross[idx])
        e_fatrq.append(predict(trq.model, feats))
        # SQ3 residual reconstruction (global + per-record range variants)
        recon = x_c[idx] + delta_sq3_global[idx]
        e_sq3.append(exact_distance_sq(q, recon))
        recon_pr = x_c[idx] + delta_sq3[idx]
        e_sq3_pr.append(exact_distance_sq(q, recon_pr))
        # INT8 whole-vector
        e_int8.append(exact_distance_sq(q, x_int8[idx]))
        # oracle: full-precision residuals (= exact)
        e_oracle.append(true_d)

    t = jnp.concatenate(trues)
    mse_fatrq = norm_mse(jnp.concatenate(e_fatrq), t)
    mse_sq3 = norm_mse(jnp.concatenate(e_sq3), t)
    mse_sq3_pr = norm_mse(jnp.concatenate(e_sq3_pr), t)
    mse_int8 = norm_mse(jnp.concatenate(e_int8), t)
    emit("fig7_mse_fatrq", 0.0, f"mse={mse_fatrq:.5f}")
    emit("fig7_mse_sq3_residual_global", 0.0,
         f"mse={mse_sq3:.5f};"
         f"fatrq_better={mse_sq3 / max(mse_fatrq, 1e-9):.1f}x")
    emit("fig7_mse_sq3_residual_perrecord", 0.0, f"mse={mse_sq3_pr:.5f}")
    emit("fig7_mse_int8", 0.0, f"mse={mse_int8:.5f}")
    emit("fig7_mse_oracle", 0.0, "mse=0.00000")


if __name__ == "__main__":
    run()
