"""Adaptive tiered placement: watch heat build up under a skewed query
trace, migrate the hot lists into HBM (and the cold ones to SSD) with
``rebalance_tiers()``, and compare the modeled per-tier cost before and
after.

    PYTHONPATH=src python examples/tiered.py

The ``TieredIndex`` starts all-warm — bit-identical to the static layout
it wraps.  Every search folds per-list access counters into an
EMA-decayed heat tracker; ``rebalance_tiers()`` turns that heat into a
hot/warm/cold placement, migrates, and bumps the index generation so
compiled executors and serving result caches drop stale entries.
"""

import jax
import numpy as np

from repro.anns import (Database, PipelineConfig, QueryPlan, TieredConfig,
                        TieredIndex, recall_at_k)
from repro.data import make_dataset
from repro.data.synthetic import brute_force_topk
from repro.memory import Tier


def zipfian_queries(ds, n=64, seed=11):
    """Seeded Zipfian trace: query popularity ∝ rank^-1.3 over database
    rows ranked by distance to one anchor — a few IVF lists absorb
    nearly all probes, the skew adaptive placement exploits."""
    x = np.asarray(ds.x)
    near = np.argsort(((x - x[0]) ** 2).sum(axis=1))
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, len(near) + 1, dtype=np.float64) ** 1.3
    rows = near[rng.choice(len(near), size=n, p=p / p.sum())]
    q = x[rows] + 0.02 * rng.standard_normal((n, x.shape[1]))
    return (q / np.linalg.norm(q, axis=1, keepdims=True)).astype(np.float32)


def per_tier(cost, nq):
    by = cost.by_tier()
    return "  ".join(f"{t.value}={by[t].accesses / nq:.1f}acc"
                     for t in Tier if by[t].accesses)


def main():
    print("building index (20k × 128d)...")
    ds = make_dataset(jax.random.PRNGKey(0), n=20_000, d=128,
                      n_queries=64, k_gt=100)
    cfg = PipelineConfig(dim=128, pq_m=16, pq_k=256, nlist=64, nprobe=8,
                         final_k=10, refine_budget=40, bound="cauchy")
    static = Database.build(jax.random.PRNGKey(1), ds.x, cfg).index

    ti = TieredIndex(static, TieredConfig(decay=0.5, hot_rows_frac=0.25,
                                          cold_rows_frac=0.2))
    db = Database.wrap(ti)
    plan = QueryPlan(front="ivf", k=10)
    q = zipfian_queries(ds)
    gt = brute_force_topk(ds.x, q, 10)
    nq = q.shape[0]

    print("replaying skewed trace on the all-warm placement "
          "(≡ static layout)...")
    warm = db.query(q, plan=plan)
    print(f"  heat observed over {ti.heat.observations} batch(es); "
          f"top-3 lists hold "
          f"{np.sort(ti.heat.heat)[-3:].sum() / ti.heat.heat.sum():.0%} "
          f"of the heat")
    print(f"  per-tier: {per_tier(warm.cost, nq)}")
    print(f"  modeled: {warm.cost.total_seconds() / nq * 1e6:.0f}us/query  "
          f"recall@10={recall_at_k(warm.ids, gt, 10):.3f}")

    out = ti.rebalance_tiers()
    occ = out["occupancy"]
    print(f"\nrebalance_tiers(): generation {out['generation']}, moves:")
    for (src, dst), rows in sorted(out["moves"].items()):
        print(f"  {src:>4} → {dst:<4} {rows} rows")
    print("  occupancy: " + "  ".join(
        f"{name}={lists}lists/{rows}rows"
        for name, (lists, rows) in occ.items()))

    print("\nreplaying the same trace on the adapted placement...")
    hot = db.query(q, plan=plan)
    print(f"  per-tier: {per_tier(hot.cost, nq)}")
    print(f"  modeled: {hot.cost.total_seconds() / nq * 1e6:.0f}us/query  "
          f"recall@10={recall_at_k(hot.ids, gt, 10):.3f}")
    saved = 1 - hot.cost.total_seconds() / warm.cost.total_seconds()
    print(f"\n  adaptive placement saves {saved:.0%} modeled time on this "
          f"trace (hot lists score exactly from HBM and skip refinement; "
          f"cold lists were barely probed)")


if __name__ == "__main__":
    main()
