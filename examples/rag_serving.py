"""End-to-end RAG serving driver (paper Fig. 1): a small LM answers batched
requests with FaTRQ retrieval in the loop, through the unified ``Database``
API — the caller's ``QueryPlan`` (backend, shards, budget) threads all the
way into the retriever instead of being silently dropped.

    PYTHONPATH=src python examples/rag_serving.py
"""

import jax
import jax.numpy as jnp

from repro.anns import Database, PipelineConfig, QueryPlan
from repro.configs import ARCHS
from repro.data import make_dataset
from repro.models import build_model
from repro.obs import trace
from repro.serving import Engine, Retriever, rag_answer


def main():
    # --- LM: reduced qwen2.5 backbone, batched decode
    cfg = ARCHS["qwen2.5-3b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = Engine(api, params, batch=4, max_len=64)

    # --- retriever: FaTRQ database over the document embedding store;
    # embedding dim = the backbone's hidden size (DESIGN.md §4)
    d = cfg.d_model
    ds = make_dataset(jax.random.PRNGKey(1), n=8_000, d=d, n_queries=4)
    pcfg = PipelineConfig(dim=d, pq_m=16, pq_k=64, nlist=32, nprobe=8,
                          final_k=5, refine_budget=20)
    db = Database.build(jax.random.PRNGKey(2), ds.x, pcfg)

    # the serving plan: validated once against the capability registry,
    # compiled once into a cached executor, reused every request
    plan = QueryPlan(front="ivf", backend="reference", micro_batch=4)
    retriever = Retriever(index=db, plan=plan)

    # embed_fn stub: mean-pool the LM's token embeddings, project to store
    def embed_fn(tokens):
        e = params["embed"][tokens].mean(axis=1)
        return e / jnp.linalg.norm(e, axis=-1, keepdims=True)

    prompts = jax.random.randint(jax.random.PRNGKey(3), (4, 8), 0,
                                 cfg.vocab)
    print("serving 4 batched RAG requests...")
    tracer = trace.Tracer()
    with trace.use(tracer):
        res = rag_answer(engine, db.index, embed_fn, prompts,
                         k=5, decode_steps=8, retriever=retriever)
    print(f"  resolved plan: {retriever.default_plan().resolve(pcfg)}")
    print(f"  retrieved ids (per request): {res.ids.tolist()}")
    print(f"  generated tokens: {res.tokens.tolist()}")
    print(f"  degraded by QoS: {res.degraded}")
    print(f"  retrieval cost breakdown: "
          f"{ {k: f'{v * 1e6:.1f}us' for k, v in res.cost.breakdown().items()} }")
    print(f"  running ledger (capacity view): "
          f"{ {k: t.accesses for k, t in retriever.total_cost.ledger.items()} }")
    print(f"  engine stats: {engine.stats}")

    # --- per-stage latency breakdown from the trace the retrieval just
    # produced: wall time (this host, measured) next to the QueryCost
    # Table-I modeled time that the perf gate pins, and their ratio.
    print("per-stage latency breakdown (traced):")
    for stage in ("front", "refine", "rerank"):
        spans = tracer.by_name(stage)
        if not spans:
            continue
        wall_ms = sum(s.wall_end_s - s.wall_start_s for s in spans) * 1e3
        model = [s.attrs["model_s"] for s in spans if "model_s" in s.attrs]
        model_ms = sum(model) * 1e3 if model else float("nan")
        drift = wall_ms / model_ms if model_ms else float("nan")
        print(f"  {stage:>7}: wall {wall_ms:8.3f} ms | "
              f"modeled {model_ms:8.3f} ms | wall/model {drift:8.1f}x "
              f"({len(spans)} span(s))")


if __name__ == "__main__":
    main()
