"""End-to-end RAG serving driver (paper Fig. 1): a small LM answers batched
requests with FaTRQ retrieval in the loop.

    PYTHONPATH=src python examples/rag_serving.py
"""

import jax
import jax.numpy as jnp

from repro.anns import PipelineConfig, build
from repro.configs import ARCHS
from repro.data import make_dataset
from repro.models import build_model
from repro.serving import Engine, rag_answer


def main():
    # --- LM: reduced qwen2.5 backbone, batched decode
    cfg = ARCHS["qwen2.5-3b"].reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = Engine(api, params, batch=4, max_len=64)

    # --- retriever: FaTRQ index over the document embedding store;
    # embedding dim = the backbone's hidden size (DESIGN.md §4)
    d = cfg.d_model
    ds = make_dataset(jax.random.PRNGKey(1), n=8_000, d=d, n_queries=4)
    pcfg = PipelineConfig(dim=d, pq_m=16, pq_k=64, nlist=32, nprobe=8,
                          final_k=5, refine_budget=20)
    index = build(jax.random.PRNGKey(2), ds.x, pcfg)

    # embed_fn stub: mean-pool the LM's token embeddings, project to store
    def embed_fn(tokens):
        e = params["embed"][tokens].mean(axis=1)
        return e / jnp.linalg.norm(e, axis=-1, keepdims=True)

    prompts = jax.random.randint(jax.random.PRNGKey(3), (4, 8), 0,
                                 cfg.vocab)
    print("serving 4 batched RAG requests...")
    gen, retrieved, cost = rag_answer(engine, index, embed_fn, prompts,
                                      k=5, decode_steps=8)
    print(f"  retrieved ids (per request): {retrieved.tolist()}")
    print(f"  generated tokens: {gen.tolist()}")
    print(f"  retrieval cost breakdown: "
          f"{ {k: f'{v * 1e6:.1f}us' for k, v in cost.breakdown().items()} }")
    print(f"  engine stats: {engine.stats}")


if __name__ == "__main__":
    main()
