"""Quickstart: build a FaTRQ database and run planned progressive-
refinement search through the unified ``Database`` API.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.anns import Database, PipelineConfig, QueryPlan, recall_at_k
from repro.data import make_dataset
from repro.memory import Tier


def main():
    print("generating synthetic embedding dataset (20k × 128d)...")
    ds = make_dataset(jax.random.PRNGKey(0), n=20_000, d=128,
                      n_queries=64, k_gt=100)

    cfg = PipelineConfig(dim=128, pq_m=16, pq_k=256, nlist=64, nprobe=8,
                         final_k=10, refine_budget=40, bound="cauchy")
    print("building index (PQ → IVF → TRQ encode → calibration)...")
    db = Database.build(jax.random.PRNGKey(1), ds.x, cfg)
    print(f"  far-memory layout: {db.index.layout.describe()} bytes/record")

    print("searching (FaTRQ progressive refinement)...")
    res = db.query(ds.queries, k=10)
    rec = recall_at_k(res.ids, ds.gt, 10)
    print(f"  resolved plan: {res.plan}")
    print(f"  nearest distance (query 0): {float(res.distances[0, 0]):.4f}")

    base = db.query(ds.queries, plan=QueryPlan(k=10, mode="baseline"))
    base_rec = recall_at_k(base.ids, ds.gt, 10)

    cost, base_cost = res.cost, base.cost
    ssd = cost.by_tier()[Tier.SSD].accesses
    ssd_b = base_cost.by_tier()[Tier.SSD].accesses
    print(f"\n  recall@10: FaTRQ={rec:.3f}  baseline={base_rec:.3f}")
    print(f"  SSD fetches/query: FaTRQ={ssd / 64:.1f}  "
          f"baseline={ssd_b / 64:.1f}  ({ssd_b / max(ssd, 1):.1f}x fewer)")
    print(f"  modeled time/query: FaTRQ={cost.total_seconds() / 64 * 1e6:.0f}us"
          f"  baseline={base_cost.total_seconds() / 64 * 1e6:.0f}us"
          f"  ({base_cost.total_seconds() / cost.total_seconds():.1f}x faster)")


if __name__ == "__main__":
    main()
