"""Train a ~100M-param LM for a few hundred steps with the fault-tolerant
loop (checkpoint/resume + straggler accounting).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses

from repro.configs import ARCHS
from repro.models import build_model
from repro.train.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()

    # ~100M config: scale the reduced family up
    cfg = dataclasses.replace(
        ARCHS[args.arch].reduced(), n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=8192)
    api = build_model(cfg)
    print(f"arch={cfg.name} params≈{cfg.params_count() / 1e6:.0f}M")

    tc = TrainConfig(steps=args.steps, batch=8, seq_len=256, lr=3e-4,
                     ckpt_every=100, ckpt_dir="/tmp/repro_train_lm")
    state = train(api, tc, resume=True)
    print(f"step={state.step} loss: first={state.losses[0]:.3f} "
          f"last={state.losses[-1]:.3f} stragglers={state.stragglers} "
          f"skipped={state.skipped}")


if __name__ == "__main__":
    main()
