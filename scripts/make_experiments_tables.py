"""Assemble EXPERIMENTS.md §Dry-run / §Roofline tables from
experiments/{proof,dryrun,perf}/*.json.

Proof cells prove the compile gate + memory for all 64 runnable cells;
unrolled cells add the roofline terms where the (slow) unrolled compile
completed in the container's CPU budget.

Usage: PYTHONPATH=src python scripts/make_experiments_tables.py
"""

from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
EXP = os.path.join(HERE, "..", "experiments")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["qwen2-vl-2b", "qwen2-72b", "qwen2.5-3b", "qwen1.5-4b",
              "gemma3-4b", "mixtral-8x22b", "phi3.5-moe-42b-a6.6b",
              "zamba2-1.2b", "whisper-medium", "xlstm-1.3b"]


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}µs"


def fmt_b(x):
    for unit, div in [("TiB", 2**40), ("GiB", 2**30), ("MiB", 2**20),
                      ("KiB", 2**10)]:
        if x >= div:
            return f"{x / div:.2f}{unit}"
    return f"{x:.0f}B"


def load(sub):
    cells = {}
    for path in glob.glob(os.path.join(EXP, sub, "*.json")):
        with open(path) as f:
            r = json.load(f)
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def main():
    proof = load("proof")
    roof = load("dryrun")
    n_ok = sum(r["status"] == "ok" for r in proof.values())
    n_skip = sum(r["status"] == "skipped" for r in proof.values())
    n_err = len(proof) - n_ok - n_skip

    print("### Dry-run compile gate (all 80 cells)\n")
    print(f"**{n_ok} compiled OK, {n_skip} skipped per spec, {n_err} "
          f"failed.**  Peak memory = deployment (scan) module, per device; "
          f"CPU-backend bf16→f32 convert buffers inflate some temps ~2× "
          f"(absent on TPU — noted per cell where dominant).\n")
    print("| arch | shape | single: peak mem | multipod: peak mem | "
          "notes |")
    print("|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            rs = proof.get((a, s, "single"))
            rm = proof.get((a, s, "multipod"))
            if rs is None and rm is None:
                continue
            if rs and rs["status"] == "skipped":
                print(f"| {a} | {s} | skipped | skipped | "
                      f"{rs['reason'][:70]} |")
                continue

            def cell(r):
                if r is None:
                    return "—"
                if r["status"] != "ok":
                    return "**ERR**"
                return fmt_b(r["peak_memory_bytes"])
            note = ""
            meta = (rs or rm).get("meta", {})
            bits = []
            if meta.get("num_micro", 1) > 1:
                bits.append(f"micro={meta['num_micro']}")
            if meta.get("seq_parallel"):
                bits.append("SP")
            if meta.get("flash_decode"):
                bits.append("flash-decode")
            note = ",".join(bits)
            print(f"| {a} | {s} | {cell(rs)} | {cell(rm)} | {note} |")

    print("\n### Roofline terms (unrolled modules; single-pod unless "
          "noted)\n")
    print("| arch | shape | mesh | compute | memory(UB) | collective | "
          "bottleneck | useful-FLOPs | MFU | MFU(opt) |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for m in ("single", "multipod"):
                r = roof.get((a, s, m))
                if not r or r["status"] != "ok":
                    continue
                print(f"| {a} | {s} | {m} | {fmt_s(r['compute_s'])} | "
                      f"{fmt_s(r['memory_s'])} | "
                      f"{fmt_s(r['collective_s'])} | "
                      f"**{r['bottleneck']}** | "
                      f"{r['useful_flops_ratio']:.2f} | {r['mfu']:.3f} | "
                      f"{r.get('mfu_optimistic', 0):.3f} |")

    perf = {}
    for path in glob.glob(os.path.join(EXP, "perf", "*.json")):
        with open(path) as f:
            r = json.load(f)
        perf[os.path.basename(path)] = r
    if perf:
        print("\n### Perf variants\n")
        print("| cell | variant | compute | memory(UB) | collective | "
              "peak mem |")
        print("|---|---|---|---|---|---|")
        for name, r in sorted(perf.items()):
            if r["status"] != "ok":
                continue
            print(f"| {r['arch']}×{r['shape']}×{r['mesh']} | "
                  f"{r.get('variant')} | {fmt_s(r['compute_s'])} | "
                  f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                  f"{fmt_b(r['peak_memory_bytes'])} |")


if __name__ == "__main__":
    main()
