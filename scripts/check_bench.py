#!/usr/bin/env python
"""CI perf-regression gate over the committed BENCH_*.json baselines.

Compares a freshly generated bench record file against the baseline
committed at HEAD and fails (exit 1) on regression.  Two modes, matching
what is actually comparable across machines:

* ``--bench serving`` — the serving bench's headline metrics are
  VIRTUAL-clock / Table-I-modeled numbers (``us_per_call`` is the
  modeled p50, ``p99_us``, ``qps_sustained``, ``cost_total_s``), fully
  deterministic for a seeded trace on any machine — so they gate hard:
  a fresh record worse than baseline by more than ``--tolerance``
  (default 25%) fails.  Records match on ``(name, devices)``; baseline
  records with no fresh counterpart are skipped (a CI leg only produces
  its own device count).
* ``--bench kernels`` — kernel micro-bench numbers are WALL time on the
  runner, not comparable across machines; the gate only checks that
  every baseline record name is still produced (a vanished record means
  a bench regressed into not running).
* ``--bench tiered`` — tiered-placement numbers are modeled like the
  serving bench's and gate the same way, PLUS the headline invariant
  from the fresh run: ``tiered_skewed_policy`` must model strictly
  cheaper than ``tiered_skewed_warm``.  Tiered records carry no
  ``devices`` field — the datapath is per-device, so every CI leg must
  reproduce one baseline.

The baseline is read from ``git show HEAD:<file>`` so a smoke step that
overwrote the workspace copy (bench scripts write in place) cannot
compare a file against itself; falls back to the on-disk file outside a
git checkout.

Usage (as wired in .github/workflows/ci.yml):
    python benchmarks/bench_serving.py --devices 8 --requests 48 --rates 4000
    python scripts/check_bench.py --bench serving
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys

SERVING_FILE = "BENCH_bench_serving.json"
KERNELS_FILE = "BENCH_bench_kernels.json"
TIERED_FILE = "BENCH_bench_tiered.json"

# (metric, higher_is_worse) — every one a virtual-clock/modeled number
SERVING_METRICS = (("us_per_call", True), ("p99_us", True),
                   ("cost_total_s", True), ("qps_sustained", False))
TIERED_METRICS = (("us_per_call", True), ("cost_total_s", True),
                  ("recall_at_k", False))


def load_baseline(path: str) -> dict:
    """The committed baseline: HEAD's copy when available (the workspace
    copy may have just been overwritten by the smoke run), else disk."""
    try:
        out = subprocess.run(["git", "show", f"HEAD:{path}"],
                             capture_output=True, text=True, timeout=30)
        if out.returncode == 0 and out.stdout.strip():
            return json.loads(out.stdout)
    except (OSError, subprocess.SubprocessError):
        pass
    with open(path) as f:
        return json.load(f)


def load_fresh(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _key(rec: dict) -> tuple:
    return (rec["name"], rec.get("devices", 1))


def check_serving(baseline: dict, fresh: dict, *, tolerance: float,
                  allow_empty: bool,
                  metrics: tuple = SERVING_METRICS) -> list[str]:
    fresh_by_key = {_key(r): r for r in fresh["records"]}
    failures: list[str] = []
    compared = 0
    for base in baseline["records"]:
        new = fresh_by_key.get(_key(base))
        if new is None:
            continue          # other CI leg's device count
        for metric, higher_worse in metrics:
            if metric not in base or metric not in new:
                continue
            b, f = float(base[metric]), float(new[metric])
            compared += 1
            if b <= 0:
                continue
            ratio = f / b
            bad = ratio > 1 + tolerance if higher_worse \
                else ratio < 1 - tolerance
            arrow = "↑" if f > b else "↓"
            line = (f"{base['name']} devices={base.get('devices', 1)} "
                    f"{metric}: {b:.6g} → {f:.6g} ({arrow}{abs(ratio - 1):.1%})")
            if bad:
                failures.append(line)
                print(f"FAIL  {line}")
            else:
                print(f"ok    {line}")
    if compared == 0 and not allow_empty:
        failures.append("no comparable (name, devices) records between "
                        "baseline and fresh — gate checked nothing")
    return failures


def check_tiered(baseline: dict, fresh: dict, *, tolerance: float,
                 allow_empty: bool) -> list[str]:
    """Tiered-placement gate: modeled metrics compare against baseline
    (they are Table-I numbers over a seeded trace, so they gate hard),
    every baseline record must still be produced (tiered records carry no
    device field — both CI legs reproduce the same numbers), and the
    headline invariant must hold in the FRESH records: under the Zipfian
    trace, the policy-on placement is strictly cheaper than all-warm."""
    failures = check_serving(
        baseline, fresh, tolerance=tolerance, allow_empty=allow_empty,
        metrics=TIERED_METRICS)
    fresh_by_name = {r["name"]: r for r in fresh["records"]}
    for name in sorted(r["name"] for r in baseline["records"]):
        if name not in fresh_by_name:
            failures.append(f"tiered record vanished: {name}")
            print(f"FAIL  tiered record vanished: {name}")
    warm = fresh_by_name.get("tiered_skewed_warm")
    policy = fresh_by_name.get("tiered_skewed_policy")
    if warm and policy:
        w, p = float(warm["cost_total_s"]), float(policy["cost_total_s"])
        line = (f"invariant skewed policy < warm: {p:.6g} vs {w:.6g} "
                f"({1 - p / w:+.1%} saved)")
        if p < w:
            print(f"ok    {line}")
        else:
            failures.append(line)
            print(f"FAIL  {line}")
    elif not allow_empty:
        failures.append("tiered_skewed_{warm,policy} records missing — "
                        "invariant checked nothing")
    return failures


def check_kernels(baseline: dict, fresh: dict, *, allow_empty: bool
                  ) -> list[str]:
    base_names = {r["name"] for r in baseline["records"]}
    fresh_names = {r["name"] for r in fresh["records"]}
    missing = sorted(base_names - fresh_names)
    for name in sorted(base_names & fresh_names):
        print(f"ok    {name} still produced")
    if not base_names and not allow_empty:
        return ["baseline has no kernel records — gate checked nothing"]
    return [f"kernel record vanished: {name}" for name in missing]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", choices=("serving", "kernels", "tiered"),
                    required=True)
    ap.add_argument("--fresh", default=None,
                    help="freshly generated record file (default: the "
                         "bench's BENCH_*.json in the workspace)")
    ap.add_argument("--baseline", default=None,
                    help="baseline record file (default: HEAD's copy of "
                         "the same file)")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional regression on serving "
                         "metrics (default 0.25)")
    ap.add_argument("--allow-empty", action="store_true",
                    help="do not fail when nothing was comparable")
    args = ap.parse_args(argv)

    default = {"serving": SERVING_FILE, "kernels": KERNELS_FILE,
               "tiered": TIERED_FILE}[args.bench]
    fresh = load_fresh(args.fresh or default)
    baseline = load_baseline(args.baseline or default)

    if args.bench == "serving":
        failures = check_serving(baseline, fresh, tolerance=args.tolerance,
                                 allow_empty=args.allow_empty)
    elif args.bench == "tiered":
        failures = check_tiered(baseline, fresh, tolerance=args.tolerance,
                                allow_empty=args.allow_empty)
    else:
        failures = check_kernels(baseline, fresh,
                                 allow_empty=args.allow_empty)
    if failures:
        print(f"\n{len(failures)} perf-gate failure(s):")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nperf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
